"""Distributed serving demo: the pipelined schedule running for real.

Spawns N socket workers (separate Python processes by default), ships each
its shard of the int8 MobileNetV2 weights once, then drives requests through
the asyncio :class:`~repro.runtime.coordinator.Coordinator` — downloads for
one fused block overlap the previous block's compute and uploads, exactly as
the PR-4 transport simulator schedules them.  The run is validated on the
spot: output must be bit-exact against the single-process ``Session`` and
the measured event timeline must realize every dependency edge the
pipelined simulator predicts.  Exits nonzero if either invariant fails.

With ``--churn`` the demo becomes a scripted fault-injection run over the
elastic runtime instead: N workers serve, one is killed mid-stream, a
straggler is demoted, the dead worker rejoins — and after every transition
the output must stay bit-exact vs the single-process ``Session`` on the
surviving topology, with only the delta re-shipped (re-shipped bytes <
full setup bytes), every unchanged shard geometry hitting the warm
compiled cache (rate 1.0), recovery bounded by ``--recovery-budget``, and
zero leaked asyncio tasks after shutdown.  Exits nonzero on any violation
— the CI ``elastic-churn`` job.

Run:  PYTHONPATH=src python examples/distributed_serve.py --workers 4
      (--smoke: reduced model, 2 workers, in-process loop — the CI job)
      PYTHONPATH=src python examples/distributed_serve.py --churn
"""
import argparse
import asyncio
import json
import sys
import time

import numpy as np

from repro.core.splitting import split_model
from repro.models import mobilenet_v2, mobilenet_v2_smoke
from repro.runtime import run_distributed, worker_geometry_summary


def run_churn(args, model, name) -> int:
    """Scripted fault injection over the elastic runtime (CI elastic-churn).

    Phases: steady serve -> kill one worker mid-stream -> demote a
    straggler -> rejoin the dead worker.  Every phase's outputs must be
    bit-exact vs the single-process Session on the surviving topology.
    """
    from repro.api.planner import Objective
    from repro.api.session import Session
    from repro.core.allocation import WorkerParams
    from repro.runtime.elastic import ElasticCluster
    from repro.runtime.replan import ElasticCoordinator

    # spatial objective: band workers replicate layer weights, so replans
    # re-ship specs, not weights — the reship < full-setup invariant.
    # The full 112x112 model needs the PSRAM-class RAM budget once churn
    # skews the band allocation toward the surviving fast workers.
    ram = (512 << 10) if args.smoke else (8 << 20)
    cluster = ElasticCluster(
        model, [WorkerParams(ram_bytes=ram) for _ in range(args.workers)],
        objective=Objective(modes=("spatial",)),
        heartbeat_timeout=1e9)      # churn is injected, not timed out
    sess = Session(cluster.plan.split, precision=args.precision, seed=0)
    qm = sess.qmodel
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(model.input_shape).astype(np.float32)
          for _ in range(max(args.requests, 2))]
    print(f"{name}: churn over {args.workers} {args.spawn} worker(s), "
          f"{args.precision}, serving {len(xs)} request(s)/phase")

    async def drive():
        res = {"phases": {}, "reports": [], "leaked_tasks": None}
        ec = ElasticCoordinator(cluster, qm, precision=args.precision,
                                spawn=args.spawn,
                                log_dir=args.log_dir)
        async with ec:
            res["phases"]["steady"] = [await ec.infer(x) for x in xs]
            # kill the worker serving plan slot 0 while a request is in
            # flight: the retry path must recover it, not drop it
            victim = ec.physical_ids[0]
            t = asyncio.ensure_future(ec.infer(xs[0]))
            await asyncio.sleep(0)
            await ec.inject_failure(0)
            first = await t
            res["phases"]["kill"] = [first] + [await ec.infer(x)
                                               for x in xs[1:]]
            res["victim"] = victim
            res["victim_excluded"] = victim not in cluster.plan_worker_ids
            res["surviving_split"] = ec.split
            # straggler: last slot reports 10x step times, gets demoted
            straggler = max(ec.physical_ids)
            for _ in range(4):
                for slot in ec.physical_ids:
                    ec.report_step_time(
                        slot, 10.0 if slot == straggler else 1.0)
            await ec.rebalance()
            res["phases"]["demote"] = [await ec.infer(x) for x in xs]
            # the dead worker comes back as a fresh process
            await ec.rejoin(victim)
            res["phases"]["rejoin"] = [await ec.infer(x) for x in xs]
            res["reports"] = list(ec.reports)
            # cold-search yardstick for the warm-replan invariant: a fresh
            # Planner (empty CostCache) on the same post-rejoin topology
            from repro.api.cluster import Cluster as ApiCluster
            from repro.api.planner import Planner
            sub = ApiCluster(
                tuple(cluster.health[i].params
                      for i in cluster.alive_indices), name="cold")
            t0 = time.perf_counter()
            Planner(model, sub, cluster.sim_cfg).plan(cluster.objective)
            res["cold_search_wall_s"] = time.perf_counter() - t0
        leaked = [t for t in asyncio.all_tasks()
                  if t is not asyncio.current_task() and not t.done()]
        res["leaked_tasks"] = len(leaked)
        return res

    res = asyncio.run(drive())

    # oracle: single-process Session on the post-kill surviving topology
    # (same qmodel — int8 output is bit-exact across all split geometries)
    oracle = Session(res["surviving_split"], qmodel=qm,
                     precision=args.precision)
    ys_ref = [oracle.run(x) for x in xs]
    failures = []
    for phase, ys in res["phases"].items():
        if len(ys) != len(xs):
            failures.append(f"phase {phase}: {len(ys)}/{len(xs)} requests "
                            "served (silent drop)")
            continue
        bad = [i for i, (y, yr) in enumerate(zip(ys, ys_ref))
               if not np.array_equal(y, yr)]
        if bad:
            failures.append(f"phase {phase}: requests {bad} not bit-exact "
                            "vs single-process Session")
        else:
            print(f"  phase {phase:7s}: {len(ys)} request(s) bit-exact")
    kill_rep = res["reports"][0]
    rejoin_rep = res["reports"][-1]
    for tag, rep in [("kill", kill_rep), ("rejoin", rejoin_rep)]:
        print(f"  {tag}: downtime {rep['downtime_s']:.2f} s, reshipped "
              f"{rep['reshipped_bytes']}/{rep['full_setup_bytes']} B, "
              f"cache {rep['cache_hits']}/{rep['expected_cache_hits']} "
              f"(rate {rep['hit_rate']:.2f})")
    for rep in res["reports"]:
        if rep["reshipped_bytes"] >= rep["full_setup_bytes"]:
            failures.append(f"replan re-shipped {rep['reshipped_bytes']} B "
                            f">= full setup {rep['full_setup_bytes']} B")
        if rep["hit_rate"] != 1.0:
            failures.append(f"warm-cache hit rate {rep['hit_rate']} != 1.0 "
                            f"({rep['cache_hits']}/"
                            f"{rep['expected_cache_hits']})")
        if rep["downtime_s"] > args.recovery_budget:
            failures.append(f"recovery took {rep['downtime_s']:.1f} s > "
                            f"budget {args.recovery_budget} s")
    if rejoin_rep["cache_hits"] == 0:
        failures.append("rejoin produced zero warm-cache hits (vacuous)")
    # warm-replan search invariants: the cluster's persistent CostCache must
    # make every churn replan warm (hit rate > 0) and the rejoin replan
    # strictly faster than a cold search of the same topology
    for tag, rep in [("kill", kill_rep), ("rejoin", rejoin_rep)]:
        print(f"  {tag}: search {rep['replan_candidates_evaluated']} "
              f"candidates, hit rate {rep['replan_cache_hit_rate']:.2f}, "
              f"wall {rep['replan_search_wall_s'] * 1e3:.0f} ms "
              f"(cold {res['cold_search_wall_s'] * 1e3:.0f} ms)")
        if rep["replan_cache_hit_rate"] <= 0.0:
            failures.append(f"{tag} replan searched cold "
                            f"(cache hit rate "
                            f"{rep['replan_cache_hit_rate']})")
    if rejoin_rep["replan_search_wall_s"] >= res["cold_search_wall_s"]:
        failures.append(
            f"warm rejoin search wall {rejoin_rep['replan_search_wall_s']:.3f}"
            f" s >= cold search wall {res['cold_search_wall_s']:.3f} s")
    if not res["victim_excluded"]:
        failures.append("killed worker still in plan_worker_ids")
    if res["leaked_tasks"]:
        failures.append(f"{res['leaked_tasks']} asyncio task(s) leaked "
                        "after close()")
    print(f"  leaked tasks after close: {res['leaked_tasks']}")

    if args.timeline_out:
        doc = {"model": name, "workers": args.workers,
               "precision": args.precision,
               "phases": {k: len(v) for k, v in res["phases"].items()},
               "victim": res["victim"],
               "cold_search_wall_s": res["cold_search_wall_s"],
               "reports": res["reports"],
               "leaked_tasks": res["leaked_tasks"],
               "failures": failures}
        with open(args.timeline_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True, default=str)
        print(f"wrote churn report -> {args.timeline_out}")

    if failures:
        for msg in failures:
            print(f"CHURN VALIDATION FAILED: {msg}", file=sys.stderr)
        return 1
    print("OK")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=None,
                    help="worker count (default: 4, or 2/3 under --smoke)")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--mode", choices=("spatial", "neuron", "kernel"),
                    default="spatial")
    ap.add_argument("--precision", choices=("int8", "float"), default="int8")
    ap.add_argument("--spawn", choices=("process", "inprocess"),
                    default="process")
    ap.add_argument("--input-hw", type=int, default=112,
                    help="input resolution for the full model (paper: 112)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model + 2 workers + in-process loop "
                         "(CI distributed-smoke job)")
    ap.add_argument("--churn", action="store_true",
                    help="scripted fault injection over the elastic "
                         "runtime: kill mid-stream, demote, rejoin "
                         "(CI elastic-churn job)")
    ap.add_argument("--recovery-budget", type=float, default=120.0,
                    help="max seconds a single replan transition may take "
                         "(--churn)")
    ap.add_argument("--timeline-out", default=None,
                    help="write the validation report + measured timeline "
                         "as JSON")
    ap.add_argument("--log-dir", default=None,
                    help="directory for per-worker log files (process spawn)")
    args = ap.parse_args(argv)

    if args.smoke:
        model = mobilenet_v2_smoke()
        name = "MobileNetV2-smoke"
        if args.workers is None:
            args.workers = 3 if args.churn else 2
    else:
        model = mobilenet_v2(input_hw=(args.input_hw, args.input_hw))
        name = f"MobileNetV2@{args.input_hw}"
    if args.workers is None:
        args.workers = 4

    if args.churn:
        return run_churn(args, model, name)
    print(f"{name}: {len(model.layers)} layers, "
          f"{model.total_macs() / 1e6:.0f}M MACs -> {args.workers} "
          f"{args.spawn} worker(s), {args.precision}, mode={args.mode}")

    split = split_model(model, np.ones(args.workers), mode=args.mode)
    for g in worker_geometry_summary(split):
        print(f"  worker {g['worker']}: {g['weight_bytes'] / 1024:.0f} KB "
              f"weights, {len(g['segments'])} segment(s)")

    rep = run_distributed(split, precision=args.precision,
                          n_requests=args.requests, spawn=args.spawn,
                          log_dir=args.log_dir)

    print(f"\nsetup (connect + ship shards + jit): {rep.setup_s:.2f} s")
    print(f"bit-exact vs single-process Session:  {rep.bitexact} "
          f"(max |diff| = {rep.max_abs_diff:g})")
    print(f"dependency edges measured/predicted:  "
          f"{len(rep.measured_edges)}/{len(rep.predicted_edges)} "
          f"(superset: {rep.edges_superset})")
    print(f"request makespan measured {rep.makespan_s * 1e3:.1f} ms vs "
          f"predicted-on-MCU {rep.predicted_s * 1e3:.1f} ms "
          f"(ratio {rep.calibration_ratio:.3f} — localhost sockets, "
          f"informational)")

    if args.timeline_out:
        doc = rep.row()
        doc["events"] = [
            {"worker": e.worker, "kind": e.kind, "segment": e.segment,
             "layer": e.layer, "start_s": e.start_s, "end_s": e.end_s,
             "nbytes": e.nbytes}
            for e in (rep.timeline.events if rep.timeline else ())]
        with open(args.timeline_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote timeline -> {args.timeline_out}")

    if not (rep.bitexact and rep.edges_superset):
        print("VALIDATION FAILED", file=sys.stderr)
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
