"""Distributed serving demo: the pipelined schedule running for real.

Spawns N socket workers (separate Python processes by default), ships each
its shard of the int8 MobileNetV2 weights once, then drives requests through
the asyncio :class:`~repro.runtime.coordinator.Coordinator` — downloads for
one fused block overlap the previous block's compute and uploads, exactly as
the PR-4 transport simulator schedules them.  The run is validated on the
spot: output must be bit-exact against the single-process ``Session`` and
the measured event timeline must realize every dependency edge the
pipelined simulator predicts.  Exits nonzero if either invariant fails.

Run:  PYTHONPATH=src python examples/distributed_serve.py --workers 4
      (--smoke: reduced model, 2 workers, in-process loop — the CI job)
"""
import argparse
import json
import sys

import numpy as np

from repro.core.splitting import split_model
from repro.models import mobilenet_v2, mobilenet_v2_smoke
from repro.runtime import run_distributed, worker_geometry_summary


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--mode", choices=("spatial", "neuron", "kernel"),
                    default="spatial")
    ap.add_argument("--precision", choices=("int8", "float"), default="int8")
    ap.add_argument("--spawn", choices=("process", "inprocess"),
                    default="process")
    ap.add_argument("--input-hw", type=int, default=112,
                    help="input resolution for the full model (paper: 112)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model + 2 workers + in-process loop "
                         "(CI distributed-smoke job)")
    ap.add_argument("--timeline-out", default=None,
                    help="write the validation report + measured timeline "
                         "as JSON")
    ap.add_argument("--log-dir", default=None,
                    help="directory for per-worker log files (process spawn)")
    args = ap.parse_args(argv)

    if args.smoke:
        model = mobilenet_v2_smoke()
        name = "MobileNetV2-smoke"
        if args.workers == ap.get_default("workers"):
            args.workers = 2
    else:
        model = mobilenet_v2(input_hw=(args.input_hw, args.input_hw))
        name = f"MobileNetV2@{args.input_hw}"
    print(f"{name}: {len(model.layers)} layers, "
          f"{model.total_macs() / 1e6:.0f}M MACs -> {args.workers} "
          f"{args.spawn} worker(s), {args.precision}, mode={args.mode}")

    split = split_model(model, np.ones(args.workers), mode=args.mode)
    for g in worker_geometry_summary(split):
        print(f"  worker {g['worker']}: {g['weight_bytes'] / 1024:.0f} KB "
              f"weights, {len(g['segments'])} segment(s)")

    rep = run_distributed(split, precision=args.precision,
                          n_requests=args.requests, spawn=args.spawn,
                          log_dir=args.log_dir)

    print(f"\nsetup (connect + ship shards + jit): {rep.setup_s:.2f} s")
    print(f"bit-exact vs single-process Session:  {rep.bitexact} "
          f"(max |diff| = {rep.max_abs_diff:g})")
    print(f"dependency edges measured/predicted:  "
          f"{len(rep.measured_edges)}/{len(rep.predicted_edges)} "
          f"(superset: {rep.edges_superset})")
    print(f"request makespan measured {rep.makespan_s * 1e3:.1f} ms vs "
          f"predicted-on-MCU {rep.predicted_s * 1e3:.1f} ms "
          f"(ratio {rep.calibration_ratio:.3f} — localhost sockets, "
          f"informational)")

    if args.timeline_out:
        doc = rep.row()
        doc["events"] = [
            {"worker": e.worker, "kind": e.kind, "segment": e.segment,
             "layer": e.layer, "start_s": e.start_s, "end_s": e.end_s,
             "nbytes": e.nbytes}
            for e in (rep.timeline.events if rep.timeline else ())]
        with open(args.timeline_out, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
        print(f"wrote timeline -> {args.timeline_out}")

    if not (rep.bitexact and rep.edges_superset):
        print("VALIDATION FAILED", file=sys.stderr)
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
