"""Train a small qwen3-family LM end to end on synthetic data: data pipeline
with prefetch, AdamW + cosine schedule, checkpoint/restart, and optional int8
gradient compression.  (~20M params by default so a few hundred steps run on
CPU; pass --full100m for a ~100M-param config if you have the patience.)

Run:  PYTHONPATH=src python examples/train_small_lm.py --steps 200
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--full100m", action="store_true")
    args = ap.parse_args()

    cfg = get_config("qwen3-14b-smoke")
    if args.full100m:
        cfg = dataclasses.replace(cfg, name="qwen3-100m", n_layers=8,
                                  d_model=512, n_heads=8, n_kv_heads=4,
                                  head_dim=64, d_ff=1536, vocab_size=50304)
    else:
        cfg = dataclasses.replace(cfg, n_layers=6, d_model=256, n_heads=8,
                                  n_kv_heads=4, head_dim=32, d_ff=512,
                                  vocab_size=2048)
    print(f"training {cfg.name}: {cfg.n_params()/1e6:.1f}M params "
          f"(analytic), {args.steps} steps @ batch {args.batch} x seq {args.seq}")
    _, _, losses = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 4, 10),
        lr=args.lr, compress_grads=args.compress_grads, log_every=20)
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ckpts in {args.ckpt_dir}; rerun to resume)")


if __name__ == "__main__":
    main()
