"""Serve a reduced LM with batched requests: prefill builds the KV cache,
then batched greedy decode — the serve_step path the decode_32k/long_500k
dry-run cells lower, exercised with real numbers on CPU.  Uses the flash-
decode Pallas kernel (interpret mode) for the attention-vs-cache hot spot and
cross-checks it against the model's own decode path.

Run:  PYTHONPATH=src python examples/lm_decode_serve.py --tokens 16
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.decode_attn.ops import flash_decode, flash_decode_ref
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    key = jax.random.PRNGKey(0)
    params = lm.init_model(cfg, key)
    B, S = args.batch, args.prompt_len
    max_seq = S + args.tokens + 1
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    print(f"== prefill {B} requests x {S} tokens ({cfg.name}) ==")
    cache = lm.init_cache(cfg, B, max_seq=max_seq)
    t0 = time.perf_counter()
    logits, cache = lm.forward(params, {"tokens": prompts}, cfg,
                               mode="prefill", cache=cache)
    print(f"prefill: {(time.perf_counter()-t0)*1e3:.0f} ms "
          f"({B*S} tokens)")

    print(f"== batched greedy decode of {args.tokens} tokens ==")
    step = jax.jit(lambda p, c, t: lm.forward(p, {"tokens": t}, cfg,
                                              mode="decode", cache=c))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    dt = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in outs], axis=1)
    print(f"decode: {dt/args.tokens*1e3:.1f} ms/token/batch "
          f"({B*args.tokens/dt:.0f} tok/s aggregate)")
    for b in range(min(B, 2)):
        print(f"  request {b}: {gen[b].tolist()}")

    print("== flash-decode kernel cross-check on the live cache ==")
    blk = cache["stacks"][0]["0_attn"]
    ck, cv = np.asarray(blk["k"][0]), np.asarray(blk["v"][0])
    hd = cfg.resolved_head_dim
    q = jax.random.normal(key, (B, 1, cfg.n_kv_heads, cfg.q_groups, hd))
    lens = np.full(B, int(cache["pos"]), np.int32)
    got = flash_decode(q, ck, cv, lens, block_s=32)
    exp = flash_decode_ref(q, ck, cv, lens)
    print(f"kernel vs oracle max|err|: "
          f"{float(jnp.max(jnp.abs(got-exp))):.2e}")


if __name__ == "__main__":
    main()
