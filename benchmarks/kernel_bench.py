"""Kernel micro-benchmarks: wall time of the jitted wrappers on this host
(interpret-mode Pallas on CPU — structural check + ref-path timing; TPU is
the performance target) plus the analytic FLOP counts used in §Roofline.

Besides the CSV rows for ``run.py``, each benchmarked kernel writes a
``kernels`` section entry into the shared ``BENCH_executor.json``:

    {"<kernel>": {"ref_us", "impl_us", "speedup"}}

``speedup`` = ref_us / impl_us, a pure on-host ratio the CI regression gate
(``check_regression.py --sections ... kernels``) tracks for drift — absolute
wall times vary across runners, the ratio between two paths timed in the
same process does not (to within the gate's tolerance).

Run:  PYTHONPATH=src python -m benchmarks.kernel_bench
"""
from __future__ import annotations

import json
import time

import numpy as np


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_section() -> dict[str, dict]:
    """Time each kernel's reference vs Pallas wrapper and return the
    ``kernels`` BENCH section (per-kernel merge keys)."""
    rng = np.random.default_rng(0)
    section: dict[str, dict] = {}

    def entry(name, ref_us, impl_us, note=""):
        section[name] = dict(ref_us=round(ref_us, 1),
                             impl_us=round(impl_us, 1),
                             speedup=round(ref_us / impl_us, 3))
        if note:
            section[name]["note"] = note

    from repro.kernels.qgemm.ops import qgemm_padded
    from repro.kernels.qgemm.ref import qgemm_ref
    m = k = n = 256
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    s = np.ones(n, np.float32)
    b = np.zeros(n, np.float32)
    flops = 2 * m * k * n
    us_ref = _time(qgemm_ref, x, w, s, b)
    entry("qgemm_256", us_ref, _time(qgemm_padded, x, w, s, b),
          note=f"ref {flops / us_ref / 1e3:.2f} GFLOP/s")

    from repro.kernels.dwconv.ops import dwconv, dwconv_bands, dwconv_ref
    c, hw = 96, 56
    xd = rng.integers(-127, 128, (c, hw, hw)).astype(np.int8)
    wd = rng.integers(-127, 128, (c, 3, 3)).astype(np.int8)
    sd = np.ones(c, np.float32)
    bd = np.zeros(c, np.float32)
    entry("dwconv_96x56", _time(dwconv_ref, xd, wd, sd, bd),
          _time(dwconv, xd, wd, sd, bd))

    # the fused-band grid (executor hot path): 4 bands of a 56-row map,
    # pre-gathered windows vs 4 independent single-window reference calls
    bands, rows_per = 4, 14
    xb = rng.integers(-127, 128,
                      (bands, c, rows_per + 2, hw + 2)).astype(np.int8)

    def bands_ref(xb, wd, sd, bd):
        outs = [dwconv_ref(xb[i, :, 1:-1, 1:-1], wd, sd, bd)
                for i in range(bands)]
        return np.stack([np.asarray(o) for o in outs])

    entry("dwconv_bands_4x96x14", _time(bands_ref, xb, wd, sd, bd),
          _time(dwconv_bands, xb, wd, sd, bd),
          note="band axis on the Pallas grid: 1 call vs bands dispatches")

    from repro.kernels.decode_attn.ops import flash_decode, flash_decode_ref
    B, K, G, HD, S = 2, 8, 5, 128, 2048
    q = rng.standard_normal((B, 1, K, G, HD)).astype(np.float32)
    ck = rng.standard_normal((B, S, K, HD)).astype(np.float32)
    cv = rng.standard_normal((B, S, K, HD)).astype(np.float32)
    lens = np.full(B, S, np.int32)
    entry("decode_attn_2k", _time(flash_decode_ref, q, ck, cv, lens),
          _time(flash_decode, q, ck, cv, lens),
          note=f"cache={ck.nbytes * 2 / 2**20:.0f}MiB")
    return section


def bench_kernels() -> list[tuple]:
    """run.py suite entry: persist the ``kernels`` BENCH section (merged
    per-kernel into the shared JSON), return CSV rows."""
    from benchmarks.executor_bench import merge_sections

    section = kernel_section()
    merge_sections(kernels=section)
    rows = []
    for name, e in section.items():
        rows.append((f"{name}_ref", e["ref_us"], e.get("note", "")))
        rows.append((f"{name}_pallas", e["impl_us"],
                     f"speedup={e['speedup']}x vs ref (interpret on CPU)"))
    return rows


def main() -> None:
    from benchmarks.executor_bench import merge_sections

    section = kernel_section()
    payload = merge_sections(kernels=section)
    print(json.dumps({"kernels": payload["kernels"]}, indent=2))


if __name__ == "__main__":
    main()
