"""Kernel micro-benchmarks: wall time of the jitted wrappers on this host
(interpret-mode Pallas on CPU — structural check + ref-path timing; TPU is
the performance target) plus the analytic FLOP counts used in §Roofline."""
from __future__ import annotations

import time

import numpy as np


def _time(fn, *args, iters=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels() -> list[tuple]:
    rng = np.random.default_rng(0)
    rows = []

    from repro.kernels.qgemm.ops import qgemm_padded
    from repro.kernels.qgemm.ref import qgemm_ref
    m = k = n = 256
    x = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w = rng.integers(-127, 128, (k, n)).astype(np.int8)
    s = np.ones(n, np.float32)
    b = np.zeros(n, np.float32)
    us_ref = _time(qgemm_ref, x, w, s, b)
    us_pal = _time(qgemm_padded, x, w, s, b)
    flops = 2 * m * k * n
    rows.append(("qgemm_ref_256", us_ref, f"{flops/us_ref/1e3:.2f}GFLOPs"))
    rows.append(("qgemm_pallas_interp_256", us_pal, "interpret-mode"))

    from repro.kernels.dwconv.ops import dwconv, dwconv_ref
    c, hw = 96, 56
    xd = rng.integers(-127, 128, (c, hw, hw)).astype(np.int8)
    wd = rng.integers(-127, 128, (c, 3, 3)).astype(np.int8)
    sd = np.ones(c, np.float32)
    bd = np.zeros(c, np.float32)
    rows.append(("dwconv_ref_96x56", _time(dwconv_ref, xd, wd, sd, bd), ""))
    rows.append(("dwconv_pallas_interp_96x56", _time(dwconv, xd, wd, sd, bd),
                 "interpret-mode"))

    from repro.kernels.decode_attn.ops import flash_decode, flash_decode_ref
    B, K, G, HD, S = 2, 8, 5, 128, 2048
    q = rng.standard_normal((B, 1, K, G, HD)).astype(np.float32)
    ck = rng.standard_normal((B, S, K, HD)).astype(np.float32)
    cv = rng.standard_normal((B, S, K, HD)).astype(np.float32)
    lens = np.full(B, S, np.int32)
    rows.append(("decode_attn_ref_2k", _time(flash_decode_ref, q, ck, cv, lens),
                 f"cache={ck.nbytes*2/2**20:.0f}MiB"))
    rows.append(("decode_attn_pallas_interp_2k",
                 _time(flash_decode, q, ck, cv, lens), "interpret-mode"))
    return rows
