"""One benchmark per paper table/figure (§VII), driven by the simulator with
constants calibrated against the paper's measurements.

Calibration: CPM (cycles/MAC) and FLASH_NS are fitted so that (a) the
single-MCU whole-model K1 at 600 MHz matches Table I's 0.133 KB/MCycle and
(b) the K1(150)/K1(600) ratio matches 0.211/0.133 (the memory-bound growth).
The effective per-KB delay D_EFF reproduces Fig. 9's 3-MCU communication
time (TCP/ack handling on the MCUs dominates the wire time).
"""
from __future__ import annotations

import numpy as np

from repro.core.allocation import (WorkerParams, ratings_evenly, ratings_for,
                                   ratings_freq_only)
from repro.core.memory import layerwise_peak, peak_ram_per_worker, single_device_peak
from repro.core.simulator import (SimConfig, compare_modes, measured_kc,
                                  simulate, simulated_k1)
from repro.core.splitting import split_model
from repro.models import mobilenet_v2

# ---------------------------------------------------------------------------
# calibration (solved in closed form; see docstring)
# ---------------------------------------------------------------------------
_K1_600_TARGET = 0.133        # Table I (KB/MCycle at 600 MHz)
_K1_RATIO_TARGET = 0.211 / 0.133
_D_EFF = 0.0063               # s/KB effective coordinator TCP overhead (Fig 9)
# Table II was evidently measured with a lighter I/O path than Fig 9 (the
# paper's own 3-MCU totals disagree: 9.8 s in Table II case 1 vs 42.97 s in
# Fig 9); we calibrate each against its own baseline and keep one knob per
# experiment.
_D_EFF_T2 = 0.0006


def calibrated_simconfig(model) -> SimConfig:
    macs = model.total_macs()
    out_kb = sum(lyr.n_out for lyr in model.layers) / 1024.0
    # K1(f) = out_kb / (macs * (cpm + ns * f/1000) / 1e6)
    # ratio: (cpm + 0.6 ns) / (cpm + 0.15 ns) = K1_RATIO  ->  ns = a * cpm
    r = _K1_RATIO_TARGET
    a = (r - 1.0) / (0.6 - r * 0.15)
    # level: cpm * (1 + 0.6 a) = out_kb * 1e6 / (macs * K1_600)
    level = out_kb * 1e6 / (macs * _K1_600_TARGET)
    cpm = level / (1 + 0.6 * a)
    return SimConfig(cycles_per_mac=cpm, flash_ns_per_mac=a * cpm)


def _model():
    return mobilenet_v2(input_hw=(112, 112))


def table1_k1() -> list[tuple]:
    """Table I: K1 under different clock frequencies."""
    m = _model()
    cfg = calibrated_simconfig(m)
    paper = {600: 0.133, 450: 0.150, 150: 0.211}
    rows = []
    for f, target in paper.items():
        k1 = simulated_k1(m, f, cfg)
        rows.append((f"table1_k1_{f}MHz", k1, f"paper={target}"))
    return rows


_TABLE2_CASES = [
    # (freqs MHz, injected delays s/KB) — Table II's 8 cases
    ((600, 600, 600), (0, 0, 0)),
    ((600, 150, 450), (0, 0, 0)),
    ((150, 396, 528), (0, 0, 0)),
    ((450, 396, 528), (0, 0, 0)),
    ((600, 150, 450), (0.010, 0, 0.005)),
    ((450, 396, 528), (0.020, 0.007, 0.013)),
    ((600, 396, 150), (0.020, 0.005, 0.010)),
    ((600, 600, 600), (0.010, 0.020, 0.005)),
]

_TABLE2_PAPER = [(9.80, 9.80, 9.80), (20.10, 12.40, 12.52),
                 (22.30, 13.43, 13.37), (11.44, 10.75, 10.61),
                 (32.81, 33.01, 31.50), (54.73, 54.20, 47.41),
                 (53.08, 54.83, 44.45), (49.18, 49.18, 41.95)]


def table2_allocation() -> list[tuple]:
    """Table II: Evenly vs Freq-only vs rating-Optimized on 3 MCUs."""
    m = _model()
    cfg = calibrated_simconfig(m)
    k1 = simulated_k1(m, 600, cfg)
    kc = measured_kc(m, 3, cfg)
    rows = []
    for i, ((freqs, delays), paper) in enumerate(zip(_TABLE2_CASES,
                                                     _TABLE2_PAPER), 1):
        workers = [WorkerParams(f_mhz=f, d_s_per_kb=d + _D_EFF_T2)
                   for f, d in zip(freqs, delays)]
        even = simulate(m, workers, ratings_evenly(workers), cfg).total_time
        freq = simulate(m, workers, ratings_freq_only(workers), cfg).total_time
        opt = simulate(m, workers, ratings_for(workers, k1, kc), cfg).total_time
        rows.append((f"table2_case{i}",
                     f"{even:.2f}/{freq:.2f}/{opt:.2f}",
                     f"paper={paper[0]}/{paper[1]}/{paper[2]}"))
    return rows


def fig8_layer_peak_ram() -> list[tuple]:
    """Fig. 8: layer-wise peak RAM with 3 MCUs stays under the budget."""
    m = _model()
    plan = split_model(m, np.ones(3))
    lw = layerwise_peak(plan)          # (L, 3) bytes, int8
    worst = lw.max(axis=1)
    return [
        ("fig8_max_layer_peak_kb", worst.max() / 1024, "budget=512KB"),
        ("fig8_layers_over_512k", int((worst > 512 * 1024).sum()),
         f"of {len(m.layers)}"),
        ("fig8_single_mcu_peak_kb", single_device_peak(m) / 1024,
         "infeasible>512KB"),
    ]


def fig9_latency_scaling() -> list[tuple]:
    """Fig. 9: total/comm/comp on 3/5/8 MCUs (paper: 42.97/45.61/56.89 s)."""
    m = _model()
    cfg = calibrated_simconfig(m)
    paper_total = {3: 42.97, 5: 45.61, 8: 56.89}
    rows = []
    for n in (3, 5, 8):
        w = [WorkerParams(d_s_per_kb=_D_EFF)] * n
        r = simulate(m, w, cfg=cfg)
        rows.append((f"fig9_total_{n}mcu_s", r.total_time,
                     f"paper={paper_total[n]} comp={r.comp_time:.2f} "
                     f"comm={r.comm_time:.2f}"))
    return rows


def fig10_fig11_layerwise() -> list[tuple]:
    """Figs. 10-11: layer-wise comm grows / comp falls with more MCUs."""
    m = _model()
    cfg = calibrated_simconfig(m)
    rows = []
    res = {n: simulate(m, [WorkerParams(d_s_per_kb=_D_EFF)] * n, cfg=cfg)
           for n in (3, 5, 8)}
    rows.append(("fig10_comm_monotone",
                 int(res[3].comm_time < res[5].comm_time < res[8].comm_time),
                 f"{res[3].comm_time:.1f}<{res[5].comm_time:.1f}<{res[8].comm_time:.1f}"))
    rows.append(("fig11_comp_monotone",
                 int(res[3].comp_time > res[5].comp_time > res[8].comp_time),
                 f"{res[3].comp_time:.1f}>{res[5].comp_time:.1f}>{res[8].comp_time:.1f}"))
    early = res[8].layer_comm[:10].sum()
    late = res[8].layer_comm[-10:].sum()
    rows.append(("fig10_comm_concentrates_early", int(early > late),
                 f"first10={early:.1f}s last10={late:.1f}s"))
    return rows


def mode_tradeoff() -> list[tuple]:
    """Beyond the paper: kernel/neuron vs spatial partitioning on 8
    heterogeneous MCUs — the comm/peak-RAM tradeoff the spatial (patch+halo,
    MCUNetV2-style) mode buys with weight replication + halo recompute."""
    m = _model()
    cfg = calibrated_simconfig(m)
    freqs = (600, 600, 528, 450, 450, 396, 150, 150)
    workers = [WorkerParams(f_mhz=f, d_s_per_kb=_D_EFF) for f in freqs]
    k1 = simulated_k1(m, 600, cfg)
    kc = measured_kc(m, 8, cfg)
    ratings = ratings_for(workers, k1, kc)
    rows = []
    for mode, rep in compare_modes(m, workers, ratings, cfg).items():
        rows.append((f"modes_{mode}",
                     rep.total_time_s,
                     f"comm={rep.comm_time_s:.2f}s "
                     f"bytes={rep.total_bytes/1e6:.2f}MB "
                     f"peak={rep.max_peak_ram/1024:.0f}KB "
                     f"weights={rep.max_weight_bytes/1024:.0f}KB"))
    return rows


def fig12_scalability() -> list[tuple]:
    """Fig. 12: per-MCU peak memory vs N up to 120 — early gains, saturation."""
    m = _model()
    rows = []
    peaks = {}
    for n in (1, 2, 4, 8, 16, 32, 64, 120):
        peaks[n] = peak_ram_per_worker(split_model(m, np.ones(n))).max() / 1024
        rows.append((f"fig12_peak_kb_{n}mcu", peaks[n], ""))
    gain_early = peaks[1] / peaks[8]
    gain_late = peaks[32] / peaks[120]
    rows.append(("fig12_saturation", f"{gain_early:.1f}x@8 vs {gain_late:.2f}x@120",
                 "diminishing returns"))
    return rows
