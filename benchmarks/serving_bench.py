"""Multi-tenant serving benchmark: continuous batching vs the flush-barrier
``Session``, plus SLO admission control under overload.

Persists a ``serving`` section into the shared ``BENCH_executor.json``
(via ``merge_sections``), keyed ``<config>`` (two tenants each — the
config model plus a second resolution of the same family, sharing the
cross-instance executable cache).  Per key:

* ``flush_rps`` / ``continuous_rps`` / ``batching_gain`` — the tentpole
  comparison.  The flush-barrier baseline is the honest pre-server
  serving architecture: ``n_clients`` concurrent closed-loop clients
  sharing one ``Session`` behind a lock (``Session`` is documented
  single-threaded), each submitting and then flushing until its ticket is
  fulfilled.  Client-driven flushes dispatch whatever happens to be
  pending, so the baseline burns its budget on many small ragged
  dispatches; the server's scheduler forms full bucket-padded
  micro-batches from the same offered stream.  Both sides are measured
  interleaved (round-robin, best-of-``rounds``) in the same process so
  host noise hits them alike.  ``check_regression.py --sections serving``
  gates ``continuous_batches <= flush_batches`` on every fresh row (fewer,
  fuller dispatches for identical work is structural, not a timing
  accident) and ``batching_gain >= 1.0`` on rows flagged ``gain_gated``
  (configs where dispatch overhead is a measurable fraction of batch wall
  time, so consolidation must show up as throughput; the heavy paper-model
  config sits at parity and reports its gain ungated).
* ``flush_batches`` / ``continuous_batches`` — engine dispatches each side
  needed for the same request count (the mechanism behind the gain).
* ``bitexact`` — every probe request served through the running server
  equals ``Session.run`` on the same plan, bitwise (gated).
* ``saturation_rps`` — closed-burst ceiling of tenant A
  (``loadgen.saturation_throughput``, informational wall-clock).
* ``steady_*`` — open-loop Poisson drive of BOTH tenants at a moderate
  fraction of saturation: per-tenant p50/p99 and served rate
  (informational wall-clock; this is the paper-facing serving headline).
* ``overload_*`` — tenant B re-driven open-loop at ``2 x`` its saturation
  against a tight SLO: ``overload_rejection_rate > 0`` (admission control
  must shed, gated) and ``overload_accepted_p99_s <= p99_bound_s`` (the
  accepted population's tail stays bounded near the SLO target instead of
  growing with the backlog, gated; the bound is a fixed multiple of the
  target recorded in the row).

Run:  PYTHONPATH=src python -m benchmarks.serving_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

# overload scenario constants (recorded in each row so the gate reads the
# bound it enforces): admission defends P99_TARGET_S; the accepted tail may
# wobble above it by scheduling noise but must stay under the bound —
# unbounded queueing would blow straight through it
P99_TARGET_S = 0.25
P99_BOUND_S = 4 * P99_TARGET_S
OVERLOAD_FACTOR = 2.0


def _configs(quick: bool):
    from repro.models import (mobilenet_v2, mobilenet_v2_paper,
                              mobilenet_v2_smoke)

    def smoke24():
        return mobilenet_v2(input_hw=(24, 24), width_mult=0.25,
                            num_classes=10,
                            cfg=[(1, 8, 1, 1), (6, 16, 2, 2), (6, 24, 2, 2)])

    # (key, tenant_a_model, tenant_b_model, n_clients, per_client, rounds)
    # (key, model_a, model_b, n_clients, per_client, rounds, gain_gated).
    # n_clients stays at 2x max_batch so offered concurrency can keep
    # buckets full.  gain_gated marks configs where dispatch overhead is a
    # measurable fraction of batch wall time, so fewer/fuller dispatches
    # must show up as throughput: on the heavy paper model per-sample
    # compute dwarfs dispatch overhead (a full int8 MNv2@112 bucket runs
    # seconds on one CPU core vs ~ms of dispatch), throughput sits at
    # parity, and only the dispatch-count invariant is gated.
    cfgs = [("smoke_2res", mobilenet_v2_smoke, smoke24, 16, 24, 3, True)]
    if not quick:
        cfgs.append(("mnv2_112_2tenant", mobilenet_v2_paper,
                     mobilenet_v2_smoke, 16, 3, 2, False))
    return cfgs


def _plan_for(model):
    from benchmarks.executor_bench import RATINGS
    from repro.core import split_model

    return split_model(model, np.asarray(RATINGS), mode="neuron")


def _closed_loop(n_clients: int, per_client: int, iteration) -> float:
    """Total requests/s of ``n_clients`` concurrent closed-loop clients,
    each running ``iteration()`` ``per_client`` times."""
    errors: list[BaseException] = []

    def worker():
        try:
            for _ in range(per_client):
                iteration()
        except BaseException as e:  # noqa: BLE001 — surface on the driver
            errors.append(e)

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(n_clients)]
    t0 = time.perf_counter()
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    if errors:
        raise errors[0]
    return n_clients * per_client / (time.perf_counter() - t0)


def flush_barrier_rps(session, x, n_clients: int, per_client: int) -> float:
    """The flush-barrier serving baseline: concurrent clients share one
    ``Session`` behind a lock (the documented single-threaded contract) and
    drive dispatch themselves — submit, then flush until fulfilled.  A
    flush group-commits whatever is pending, so batch sizes are whatever
    thread timing produced, not full buckets."""
    lock = threading.Lock()

    def iteration():
        with lock:
            ticket = session.submit(x)
        while not ticket.done():
            with lock:
                session.flush()

    return _closed_loop(n_clients, per_client, iteration)


def continuous_rps_fn(server, tenant: str, x, n_clients: int,
                      per_client: int) -> float:
    """Same client population against the continuous-batching server."""

    def iteration():
        server.submit(tenant, x).result(timeout=120.0)

    return _closed_loop(n_clients, per_client, iteration)


def serving_section(quick: bool = False) -> dict:
    from repro.api import Session
    from repro.serve import (SLO, Server, run_open_loop,
                             saturation_throughput)

    rng = np.random.default_rng(0)
    section: dict[str, dict] = {}
    for (key, make_a, make_b, n_clients, per_client, rounds,
         gain_gated) in _configs(quick):
        model_a, model_b = make_a(), make_b()
        plan_a, plan_b = _plan_for(model_a), _plan_for(model_b)
        xa = rng.standard_normal(model_a.input_shape).astype(np.float32)
        xb = rng.standard_normal(model_b.input_shape).astype(np.float32)

        # the flush-barrier baseline Session and tenant A share plan,
        # precision, calibration seed and buckets: same compiled executable
        base = Session(plan_a, precision="int8", max_batch=8)
        base.warmup()
        server = Server(max_inflight=2)
        sess_a = server.add_tenant(
            "a", plan_a, precision="int8", max_batch=8,
            slo=SLO(p99_target_s=None, queue_cap=None))
        sess_b = server.add_tenant(
            "b", plan_b, precision="int8", max_batch=8,
            slo=SLO(p99_target_s=P99_TARGET_S, queue_cap=4096))
        with server:
            # bit-exactness probe before any load: each request through the
            # running scheduler must equal the Session path bitwise
            bitexact = all(
                np.array_equal(server.run("a", p, timeout=120.0), base.run(p))
                for p in (rng.standard_normal(model_a.input_shape)
                          .astype(np.float32) for _ in range(8)))

            # interleaved rounds: barrier and continuous alternate so host
            # noise hits both; best-of damps one-sided slowdown spikes
            flush_best, cont_best = 0.0, 0.0
            base_batches0 = base.stats().batches
            cont_batches0 = sess_a.stats().batches
            n_round = n_clients * per_client
            for _ in range(rounds):
                flush_best = max(flush_best, flush_barrier_rps(
                    base, xa, n_clients, per_client))
                cont_best = max(cont_best, continuous_rps_fn(
                    server, "a", xa, n_clients, per_client))
            flush_batches = base.stats().batches - base_batches0
            cont_batches = sess_a.stats().batches - cont_batches0

            # per-tenant ceilings, then a steady open-loop Poisson phase on
            # both tenants at a moderate fraction of each ceiling
            sat_a = saturation_throughput(server, "a", lambda: xa,
                                          n_requests=n_round)
            sat_b = saturation_throughput(server, "b", lambda: xb,
                                          n_requests=n_round)
            # drive long enough that even a slow tenant (MNv2@112 saturates
            # near 1 req/s on one CPU core) sees ~8 expected Poisson
            # arrivals — percentiles over an empty sample are NaN noise
            steady_dur = min(20.0, max(1.5, 8.0 / (0.4 * min(sat_a, sat_b))))
            steady = run_open_loop(
                server, {"a": 0.4 * sat_a, "b": 0.4 * sat_b},
                {"a": lambda: xa, "b": lambda: xb},
                duration_s=steady_dur, seed=1)

            # overload: tenant B at 2x its ceiling; the SLO gate must shed
            # and the accepted population's p99 must stay near the target
            overload = run_open_loop(
                server, {"b": OVERLOAD_FACTOR * sat_b}, {"b": lambda: xb},
                duration_s=2.0, seed=2)["b"]

        section[key] = dict(
            tenants={"a": f"{model_a.input_shape}",
                     "b": f"{model_b.input_shape}"},
            n_clients=n_clients, per_client=per_client, rounds=rounds,
            requests_per_round=n_round,
            flush_rps=round(flush_best, 1),
            continuous_rps=round(cont_best, 1),
            batching_gain=round(cont_best / flush_best, 4),
            gain_gated=gain_gated,
            flush_batches=flush_batches,
            continuous_batches=cont_batches,
            bitexact=bool(bitexact),
            saturation_rps=round(sat_a, 1),
            saturation_b_rps=round(sat_b, 1),
            steady_offered_frac=0.4,
            steady_duration_s=round(steady_dur, 2),
            steady_a_p50_s=round(steady["a"].p50_s, 6),
            steady_a_p99_s=round(steady["a"].p99_s, 6),
            steady_b_p50_s=round(steady["b"].p50_s, 6),
            steady_b_p99_s=round(steady["b"].p99_s, 6),
            overload_offered_rps=round(overload.offered_rps, 1),
            overload_rejection_rate=round(overload.rejection_rate, 4),
            overload_accepted_p99_s=round(overload.p99_s, 6),
            p99_target_s=P99_TARGET_S,
            p99_bound_s=P99_BOUND_S,
        )
    return section


def bench_serving(quick: bool = False) -> list[tuple]:
    """run.py suite entry: persist the ``serving`` BENCH section, return
    CSV rows."""
    from benchmarks.executor_bench import merge_sections

    section = serving_section(quick)
    merge_sections(serving=section)
    rows = []
    for key, e in section.items():
        rows.append((f"serving_{key}_continuous_rps", e["continuous_rps"],
                     f"flush-barrier={e['flush_rps']} rps "
                     f"gain={e['batching_gain']}x "
                     f"batches {e['continuous_batches']} vs "
                     f"{e['flush_batches']} bitexact={e['bitexact']}"))
        rows.append((f"serving_{key}_overload_p99_s",
                     e["overload_accepted_p99_s"],
                     f"@{e['overload_offered_rps']} rps offered, "
                     f"shed {e['overload_rejection_rate']:.0%} "
                     f"(target {e['p99_target_s']}s, "
                     f"bound {e['p99_bound_s']}s)"))
    return rows


def main() -> None:
    from benchmarks.executor_bench import merge_sections

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke config only (CI)")
    args = ap.parse_args()
    section = serving_section(quick=args.quick)
    payload = merge_sections(serving=section)
    print(json.dumps({"serving": payload["serving"]}, indent=2))


if __name__ == "__main__":
    main()
