"""Distributed-runtime benchmark: the real asyncio coordinator + socket
workers vs the single-process Session and the pipelined simulator.

Persists a ``runtime`` section into the shared ``BENCH_executor.json``
(via ``merge_sections``), keyed ``<config>@<n_workers>``:

* ``setup_s`` — connect + ship shards + per-worker jit warmup (wall time,
  machine-bound, informational);
* ``request_s`` — best measured per-request makespan;
* ``predicted_s`` / ``ratio`` — pipelined-simulator makespan on the paper's
  MCU ratings and measured/predicted (localhost is not an 11.5 kB/s link,
  so the ratio is calibration data, never a gate);
* ``bitexact`` / ``edges_superset`` — the two machine-independent hard
  invariants ``check_regression.py --sections runtime`` enforces on fresh
  rows: distributed output equals the Session bytes, and the measured
  event timeline realizes every dependency edge the simulator predicts.

Run:  PYTHONPATH=src python -m benchmarks.runtime_bench [--quick]
"""
from __future__ import annotations

import json

import numpy as np


def runtime_section(quick: bool = False) -> dict:
    from repro.core.splitting import split_model
    from repro.models import mobilenet_v2_smoke
    from repro.runtime import run_distributed

    model = mobilenet_v2_smoke()
    counts = (2,) if quick else (1, 2, 4)
    spawn = "inprocess" if quick else "process"
    section = {}
    for n in counts:
        split = split_model(model, np.ones(n), mode="spatial")
        rep = run_distributed(split, precision="int8", n_requests=2,
                              spawn=spawn)
        section[f"mnv2_smoke@{n}"] = dict(
            n_workers=n,
            spawn=spawn,
            setup_s=round(rep.setup_s, 3),
            request_s=round(rep.makespan_s, 6),
            predicted_s=round(rep.predicted_s, 6),
            ratio=round(rep.calibration_ratio, 4),
            bitexact=bool(rep.bitexact),
            edges_superset=bool(rep.edges_superset),
            n_edges=len(rep.measured_edges))
    return section


def bench_runtime(quick: bool = False) -> list[tuple]:
    """run.py suite entry: persist the ``runtime`` BENCH section, return
    CSV rows."""
    from benchmarks.executor_bench import merge_sections

    section = runtime_section(quick)
    merge_sections(runtime=section)
    rows = []
    for key, e in section.items():
        rows.append((f"runtime_{key}_request_s", e["request_s"],
                     f"setup={e['setup_s']}s {e['spawn']} "
                     f"bitexact={e['bitexact']} "
                     f"edges_superset={e['edges_superset']}"))
        rows.append((f"runtime_{key}_ratio", e["ratio"],
                     f"measured/predicted (predicted={e['predicted_s']}s "
                     f"on MCU ratings; informational)"))
    return rows


def main() -> None:
    from benchmarks.executor_bench import merge_sections

    section = runtime_section()
    payload = merge_sections(runtime=section)
    print(json.dumps({"runtime": payload["runtime"]}, indent=2))


if __name__ == "__main__":
    main()
