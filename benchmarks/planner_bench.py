"""Planner search benchmark: plan-search wall time for MobileNetV2 over
1/3/8-worker heterogeneous clusters, plus the chosen plan's *deterministic*
metrics (simulated latency, max per-worker peak RAM, chosen transport and
its predicted overlap savings) — the analytic ones are machine-independent
and gated by ``check_regression.py`` against the committed baseline; the
wall time is informational.

Five sections merge into ``BENCH_executor.json`` via read-modify-write
(so this bench and ``executor_bench`` can run in either order — each
preserves the other's sections):

* ``planner`` — plan-search outcomes per {config}@{workers};
* ``transport`` — the async-transport rows: serial (Eq. 5-6) total vs
  pipelined makespan per {config}@{workers}/{mode}, all analytic;
* ``mixed`` — the mode-mixing rows per {config}@{workers}: the best
  *uniform*-mode candidate vs the plan chosen when the DP-mixed axis is
  enabled (``Objective(modes=SEARCH_MODES)``), from one shared search — the
  chosen plan may never score worse than the best uniform candidate
  (gated invariant);
* ``search`` — the plan-*search* rows per {config}@{workers}: beam vs
  prefix-ladder plan score, cold vs warm-cache replan (candidates
  evaluated / cache misses / hit rate; walls informational), and the
  transport-aware vs serial-surrogate mixing DP judged on exact simulated
  pipelined latency — the machine-independent invariants are gated by
  ``check_regression.py --sections search``;
* ``peaks`` — the analytic per-worker peak-RAM maxima (same computation as
  ``executor_bench``), so the fully-analytic CI cell (pinned-min jax) can
  regenerate and gate planner/peaks/transport/mixed/search without timing
  anything.

Run:  PYTHONPATH=src python -m benchmarks.planner_bench [--quick]
(--quick: smoke model only — the CI smoke run.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

try:
    from benchmarks.executor_bench import peaks_for
except ImportError:  # run as a plain script: benchmarks/ is sys.path[0]
    from executor_bench import peaks_for

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULT_PATH = _REPO_ROOT / "BENCH_executor.json"

WORKER_COUNTS = (1, 3, 8)
RAM_CAP = 512 * 1024
TRANSPORT_MODES = ("neuron", "spatial")
# the mixed section covers the acceptance regime: 7/8-worker heterogeneous
# demo clusters are where per-block mixing beats the best uniform plan
MIXED_WORKER_COUNTS = (3, 7, 8)
# the search section's cluster sizes (cold vs warm-cache replans, beam vs
# ladder, transport-aware vs serial-surrogate mixing DP)
SEARCH_WORKER_COUNTS = (3, 7, 8)
SEARCH_BEAM_WIDTH = 4
# total candidate-evaluation budget for the beam row (ladder evaluations
# count toward it): bounds the CI analytic cell's wall at mnv2 scale while
# leaving the beam ~2x the ladder's evaluation count to explore with
SEARCH_BUDGET = 64


def _configs(quick: bool):
    from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke
    cfgs = [("smoke", mobilenet_v2_smoke)]
    if not quick:
        cfgs.append(("mnv2_112", mobilenet_v2_paper))
    return cfgs


def planner_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    from repro.api import Cluster, InfeasibleError, Objective, Planner

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    for name, make_model in _configs(quick):
        model = make_model()
        for k in WORKER_COUNTS:
            cluster = Cluster.heterogeneous_demo(k)
            planner = Planner(model, cluster)
            objective = Objective(minimize="latency", ram_cap_bytes=RAM_CAP)
            t0 = time.perf_counter()
            try:
                plan = planner.plan(objective)
            except InfeasibleError as e:
                wall = time.perf_counter() - t0
                # the search still costs wall time; record the outcome so a
                # feasibility flip vs baseline is visible in the artifact
                data[f"{name}@{k}"] = dict(feasible=False, wall_s=round(wall, 4),
                                           binding=e.binding_constraint)
                rows.append((f"planner_{name}_w{k}", wall,
                             f"INFEASIBLE ({e.binding_constraint})"))
                continue
            wall = time.perf_counter() - t0
            data[f"{name}@{k}"] = dict(
                feasible=True, wall_s=round(wall, 4),
                plan_latency_s=round(plan.latency_s, 9),
                max_peak_ram=int(plan.max_peak_ram),
                mode=plan.mode, fusion=plan.fusion,
                transport=plan.transport,
                overlap_saved_s=round(plan.overlap_saved_s, 9),
                n_workers=plan.n_workers)
            rows.append((f"planner_{name}_w{k}", wall,
                         f"mode={plan.mode}/{plan.fusion} "
                         f"transport={plan.transport} "
                         f"workers={plan.n_workers} "
                         f"latency={plan.latency_s:.4f}s "
                         f"peak={plan.max_peak_ram / 1024:.0f}KB"))
    return rows, data


def transport_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    """Deterministic async-transport rows: serial (Eq. 5-6) total vs
    pipelined makespan for the heterogeneous demo cluster, per mode.  All
    analytic — gated by ``check_regression.py``'s ``transport`` section."""
    import dataclasses

    from repro.api import Cluster
    from repro.core import SimConfig, simulate, split_model

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    cfg = SimConfig()
    for name, make_model in _configs(quick):
        model = make_model()
        for k in WORKER_COUNTS:
            if k < 2:
                continue        # single link: the transports coincide
            workers = list(Cluster.heterogeneous_demo(k).workers)
            for mode in TRANSPORT_MODES:
                plan = split_model(model, np.ones(k), mode=mode)
                serial = simulate(model, workers, cfg=cfg, plan=plan)
                piped = simulate(
                    model, workers,
                    cfg=dataclasses.replace(cfg, transport="pipelined"),
                    plan=plan)
                key = f"{name}@{k}/{mode}"
                data[key] = dict(
                    serial_s=round(serial.total_time, 9),
                    pipelined_s=round(piped.total_time, 9),
                    overlap_saved_s=round(piped.overlap_saved_s, 9),
                    mean_link_utilization=round(
                        float(piped.timeline.link_utilization.mean()), 6),
                    max_idle_s=round(float(piped.timeline.idle_s.max()), 9))
                rows.append((f"transport_{name}_w{k}_{mode}",
                             piped.total_time,
                             f"serial={serial.total_time:.4f}s "
                             f"saved={piped.overlap_saved_s:.4f}s"))
    return rows, data


def mixed_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    """Deterministic mode-mixing rows: one latency search per config@k with
    the DP-mixed axis enabled; the best *uniform* candidate and the chosen
    plan both come from that single candidate table, so the comparison is
    internally consistent.  The chosen score can never exceed the best
    uniform score (the winner is the min over a superset) — gated as an
    invariant by ``check_regression.py``'s ``mixed`` section."""
    from repro.api import (Cluster, InfeasibleError, Objective, Planner,
                           SEARCH_MODES)

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    for name, make_model in _configs(quick):
        model = make_model()
        for k in MIXED_WORKER_COUNTS:
            cluster = Cluster.heterogeneous_demo(k)
            planner = Planner(model, cluster)
            objective = Objective(minimize="latency", ram_cap_bytes=RAM_CAP,
                                  modes=SEARCH_MODES)
            t0 = time.perf_counter()
            try:
                plan = planner.plan(objective)
            except InfeasibleError as e:
                wall = time.perf_counter() - t0
                data[f"{name}@{k}"] = dict(feasible=False,
                                           wall_s=round(wall, 4),
                                           binding=e.binding_constraint)
                rows.append((f"mixed_{name}_w{k}", wall,
                             f"INFEASIBLE ({e.binding_constraint})"))
                continue
            wall = time.perf_counter() - t0
            uniform = [c for c in plan.candidates
                       if c.feasible and c.mode != "mixed"]
            entry = dict(
                feasible=True, wall_s=round(wall, 4),
                mixed_s=round(plan.score, 9),
                mode=plan.mode, transport=plan.transport,
                max_peak_ram=int(plan.max_peak_ram),
                n_workers=plan.n_workers)
            # only a mixed assignment may fit where no uniform plan does
            # (mixing strictly widens feasibility); the gate's metric and
            # invariant checks both tolerate the missing key
            tag = "no feasible uniform"
            if uniform:
                best_uniform_s = min(c.score for c in uniform)
                entry["best_uniform_s"] = round(best_uniform_s, 9)
                tag = f"best_uniform={best_uniform_s:.4f}s"
            if plan.assignment is not None:
                entry["assignment"] = list(plan.assignment)
            data[f"{name}@{k}"] = entry
            rows.append((f"mixed_{name}_w{k}", plan.latency_s,
                         f"mode={plan.mode} {tag} "
                         f"chosen={plan.score:.4f}s"))
    return rows, data


def search_metrics(quick: bool = False,
                   counts: tuple[int, ...] = SEARCH_WORKER_COUNTS
                   ) -> tuple[list[tuple], dict]:
    """The plan-*search* rows per config@k: how the shared cost-model layer
    (``core.search``) changes what the planner finds and how fast.

    Three comparisons per row, all gated by ``check_regression.py``'s
    ``search`` section on machine-independent quantities (the walls are
    informational):

    * **beam vs ladder** — the same objective searched with
      ``beam_width=SEARCH_BEAM_WIDTH`` vs the default prefix ladder; the
      beam always evaluates the ladder prefixes too, so its plan score may
      never be worse (gated on every fresh row);
    * **warm vs cold replan** — the lowest-rated worker dies and the
      survivors are re-planned against the cache the initial search filled
      (the ``ElasticCluster`` path) vs a cold planner on the same survivor
      topology: the warm replan must *evaluate* (cache-miss) strictly fewer
      candidates and show a hit rate > 0 (both gated);
    * **transport-aware vs serial-surrogate mixing DP** — both DP variants'
      chosen assignments judged on the exact simulated *pipelined* latency
      of their plans; the transport-aware path re-ranks both candidates so
      it is never worse, and must strictly win on at least one mnv2_112
      row (the PR-5 follow-on's acceptance regime).
    """
    import dataclasses

    from repro.api import Cluster, InfeasibleError, Objective, Planner
    from repro.api.plan import build_split_plan
    from repro.core import (CostCache, SimConfig, measured_kc, ratings_for,
                            simulate, simulated_k1)
    from repro.core.mixed import search_mixed_assignment

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    cfg = SimConfig()
    for name, make_model in _configs(quick):
        model = make_model()
        for k in counts:
            cluster = Cluster.heterogeneous_demo(k)
            objective = Objective(minimize="latency", ram_cap_bytes=RAM_CAP)
            cache = CostCache()
            # cold ladder search — fills the shared cache
            planner = Planner(model, cluster, cache=cache)
            t0 = time.perf_counter()
            ladder = planner.plan(objective)
            cold_wall = time.perf_counter() - t0
            cold = planner.last_stats
            # beam over non-prefix subsets, same cache (ladder prefixes hit)
            beam_planner = Planner(model, cluster, cache=cache)
            t0 = time.perf_counter()
            beam = beam_planner.plan(dataclasses.replace(
                objective, beam_width=SEARCH_BEAM_WIDTH,
                search_budget=SEARCH_BUDGET))
            beam_wall = time.perf_counter() - t0
            beam_stats = beam_planner.last_stats
            # warm replan: the lowest-rated worker dies; survivors re-planned
            # against the same cache (what ElasticCluster does on churn) ...
            victim = int(planner._worker_order()[-1])
            survivors = Cluster(
                tuple(w for i, w in enumerate(cluster.workers)
                      if i != victim), name=f"demo[{k}]-1")
            # a shrunk survivor cluster can be infeasible at the paper scale
            # (mnv2@3 minus one worker blows the RAM cap) — the search still
            # runs every candidate, so the warm-vs-cold stats stay valid
            warm_planner = Planner(model, survivors, cache=cache)
            t0 = time.perf_counter()
            try:
                warm_planner.plan(objective)
            except InfeasibleError:
                pass
            warm_wall = time.perf_counter() - t0
            warm = warm_planner.last_stats
            # ... vs the same replan from a cold cache (the yardstick)
            cold_planner = Planner(model, survivors)
            t0 = time.perf_counter()
            try:
                cold_planner.plan(objective)
            except InfeasibleError:
                pass
            cold_replan_wall = time.perf_counter() - t0
            cold_replan = cold_planner.last_stats
            # transport-aware vs serial-surrogate mixing DP, both judged on
            # the exact simulated pipelined latency of their chosen plans
            workers = list(cluster.workers)
            ratings = ratings_for(
                workers, simulated_k1(model, cluster.max_f_mhz, cfg),
                measured_kc(model, k, cfg))
            caps = np.array([min(w.ram_bytes, RAM_CAP) for w in workers],
                            dtype=np.float64)
            pcfg = dataclasses.replace(cfg, transport="pipelined")

            def _pipe_latency(search):
                split = build_split_plan(
                    model, ratings, "mixed", assignment=search.assignment,
                    block_workers=search.block_workers)
                return simulate(model, workers, ratings, pcfg, plan=split,
                                compute_peak=False).total_time

            dp_cache = CostCache()   # the two DPs share block-cost tables
            s_serial = search_mixed_assignment(
                model, workers, ratings, cfg, ram_caps=caps, cache=dp_cache)
            s_pipe = search_mixed_assignment(
                model, workers, ratings, cfg, ram_caps=caps,
                transport="pipelined", cache=dp_cache)
            dp_serial_s = _pipe_latency(s_serial)
            # the planner's transport-aware path re-ranks both assignments
            # under the exact pipelined simulate — min() is what it deploys
            dp_transport_s = min(dp_serial_s, _pipe_latency(s_pipe))
            entry = dict(
                ladder_score=round(ladder.score, 9),
                beam_score=round(beam.score, 9),
                beam_width=SEARCH_BEAM_WIDTH,
                beam_subsets=beam_stats.subsets_explored,
                cold_wall_s=round(cold_wall, 4),
                beam_wall_s=round(beam_wall, 4),
                warm_wall_s=round(warm_wall, 4),
                cold_replan_wall_s=round(cold_replan_wall, 4),
                cold_candidates=cold.candidates_evaluated,
                cold_misses=cold.cache_misses,
                warm_candidates=warm.candidates_evaluated,
                warm_misses=warm.cache_misses,
                warm_hit_rate=round(warm.cache_hit_rate, 6),
                cold_replan_candidates=cold_replan.candidates_evaluated,
                cold_replan_misses=cold_replan.cache_misses,
                dp_serial_pipelined_s=round(dp_serial_s, 9),
                dp_transport_pipelined_s=round(dp_transport_s, 9),
                transport_dp_win=bool(
                    dp_transport_s < dp_serial_s * (1.0 - 1e-12)))
            data[f"{name}@{k}"] = entry
            rows.append((f"search_{name}_w{k}", cold_wall,
                         f"beam={beam.score:.4f}s ladder={ladder.score:.4f}s "
                         f"warm_hits={warm.cache_hits}/"
                         f"{warm.candidates_evaluated} "
                         f"dp_win={entry['transport_dp_win']}"))
    return rows, data


def analytic_peaks(quick: bool = False) -> dict:
    """The ``peaks`` section via the same :func:`executor_bench.peaks_for`
    the timed bench uses — here so the analytic-only CI cell can refresh it
    without running any timed benchmark."""
    return {name: peaks_for(make_model())
            for name, make_model in _configs(quick)}


def merge_results(planner: dict, transport: dict, mixed: dict,
                  peaks: dict, search: dict | None = None) -> dict:
    """Read-modify-write the shared JSON: update only our sections, and
    merge each of them per key — a ``--quick`` run refreshes the smoke
    entries without erasing the committed full-model (mnv2_112) coverage
    the analytic CI gate compares against."""
    payload: dict = {}
    if RESULT_PATH.exists():
        try:
            payload = json.loads(RESULT_PATH.read_text())
        except json.JSONDecodeError:
            payload = {}
    payload.setdefault("benchmark", "executor_eager_vs_compiled")
    sections = [("planner", planner), ("transport", transport),
                ("mixed", mixed), ("peaks", peaks)]
    if search is not None:
        sections.append(("search", search))
    for section, fresh in sections:
        merged = dict(payload.get(section, {}))
        merged.update(fresh)
        payload[section] = merged
    RESULT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def _collect(quick: bool) -> tuple[list[tuple], dict]:
    rows, planner = planner_metrics(quick=quick)
    t_rows, transport = transport_metrics(quick=quick)
    m_rows, mixed = mixed_metrics(quick=quick)
    s_rows, search = search_metrics(quick=quick)
    peaks = analytic_peaks(quick=quick)
    payload = merge_results(planner, transport, mixed, peaks, search)
    return rows + t_rows + m_rows + s_rows, payload


def bench_planner(quick: bool = False) -> list[tuple]:
    """run.py suite entry: benchmark, merge JSON, return CSV rows."""
    rows, _ = _collect(quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke model only (CI)")
    ap.add_argument("--search-n8", action="store_true",
                    help="refresh only the search section at N=8 "
                         "(the nightly wide-cluster search run)")
    args = ap.parse_args()
    if args.search_n8:
        _, search = search_metrics(quick=args.quick, counts=(8,))
        payload = merge_results({}, {}, {}, {}, search)
        print(json.dumps(payload["search"], indent=2))
        return
    _, payload = _collect(args.quick)
    print(json.dumps({k: payload[k]
                      for k in ("planner", "transport", "mixed", "search")},
                     indent=2))


if __name__ == "__main__":
    main()
