"""Planner search benchmark: plan-search wall time for MobileNetV2 over
1/3/8-worker heterogeneous clusters, plus the chosen plan's *deterministic*
metrics (simulated latency, max per-worker peak RAM) — those two are
analytic, machine-independent, and gated by ``check_regression.py`` against
the committed baseline; the wall time is informational.

Results merge into ``BENCH_executor.json`` under the ``planner`` key via
read-modify-write, so this bench and ``executor_bench`` can run in either
order (each preserves the other's sections).

Run:  PYTHONPATH=src python -m benchmarks.planner_bench [--quick]
(--quick: smoke model only — the CI smoke run.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULT_PATH = _REPO_ROOT / "BENCH_executor.json"

WORKER_COUNTS = (1, 3, 8)
RAM_CAP = 512 * 1024


def planner_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    from repro.api import Cluster, InfeasibleError, Objective, Planner
    from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke

    cfgs = [("smoke", mobilenet_v2_smoke)]
    if not quick:
        cfgs.append(("mnv2_112", mobilenet_v2_paper))
    rows: list[tuple] = []
    data: dict[str, dict] = {}
    for name, make_model in cfgs:
        model = make_model()
        for k in WORKER_COUNTS:
            cluster = Cluster.heterogeneous_demo(k)
            planner = Planner(model, cluster)
            objective = Objective(minimize="latency", ram_cap_bytes=RAM_CAP)
            t0 = time.perf_counter()
            try:
                plan = planner.plan(objective)
            except InfeasibleError as e:
                wall = time.perf_counter() - t0
                # the search still costs wall time; record the outcome so a
                # feasibility flip vs baseline is visible in the artifact
                data[f"{name}@{k}"] = dict(feasible=False, wall_s=round(wall, 4),
                                           binding=e.binding_constraint)
                rows.append((f"planner_{name}_w{k}", wall,
                             f"INFEASIBLE ({e.binding_constraint})"))
                continue
            wall = time.perf_counter() - t0
            data[f"{name}@{k}"] = dict(
                feasible=True, wall_s=round(wall, 4),
                plan_latency_s=round(plan.latency_s, 9),
                max_peak_ram=int(plan.max_peak_ram),
                mode=plan.mode, fusion=plan.fusion,
                n_workers=plan.n_workers)
            rows.append((f"planner_{name}_w{k}", wall,
                         f"mode={plan.mode}/{plan.fusion} "
                         f"workers={plan.n_workers} "
                         f"latency={plan.latency_s:.4f}s "
                         f"peak={plan.max_peak_ram / 1024:.0f}KB"))
    return rows, data


def merge_results(data: dict) -> dict:
    """Read-modify-write the shared JSON: update only the planner section."""
    payload: dict = {}
    if RESULT_PATH.exists():
        try:
            payload = json.loads(RESULT_PATH.read_text())
        except json.JSONDecodeError:
            payload = {}
    payload.setdefault("benchmark", "executor_eager_vs_compiled")
    payload["planner"] = data
    RESULT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def bench_planner(quick: bool = False) -> list[tuple]:
    """run.py suite entry: benchmark, merge JSON, return CSV rows."""
    rows, data = planner_metrics(quick=quick)
    merge_results(data)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke model only (CI)")
    args = ap.parse_args()
    rows, data = planner_metrics(quick=args.quick)
    merge_results(data)
    print(json.dumps(data, indent=2))


if __name__ == "__main__":
    main()
