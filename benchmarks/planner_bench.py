"""Planner search benchmark: plan-search wall time for MobileNetV2 over
1/3/8-worker heterogeneous clusters, plus the chosen plan's *deterministic*
metrics (simulated latency, max per-worker peak RAM, chosen transport and
its predicted overlap savings) — the analytic ones are machine-independent
and gated by ``check_regression.py`` against the committed baseline; the
wall time is informational.

Four sections merge into ``BENCH_executor.json`` via read-modify-write
(so this bench and ``executor_bench`` can run in either order — each
preserves the other's sections):

* ``planner`` — plan-search outcomes per {config}@{workers};
* ``transport`` — the async-transport rows: serial (Eq. 5-6) total vs
  pipelined makespan per {config}@{workers}/{mode}, all analytic;
* ``mixed`` — the mode-mixing rows per {config}@{workers}: the best
  *uniform*-mode candidate vs the plan chosen when the DP-mixed axis is
  enabled (``Objective(modes=SEARCH_MODES)``), from one shared search — the
  chosen plan may never score worse than the best uniform candidate
  (gated invariant);
* ``peaks`` — the analytic per-worker peak-RAM maxima (same computation as
  ``executor_bench``), so the fully-analytic CI cell (pinned-min jax) can
  regenerate and gate planner/peaks/transport/mixed without timing
  anything.

Run:  PYTHONPATH=src python -m benchmarks.planner_bench [--quick]
(--quick: smoke model only — the CI smoke run.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

try:
    from benchmarks.executor_bench import peaks_for
except ImportError:  # run as a plain script: benchmarks/ is sys.path[0]
    from executor_bench import peaks_for

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULT_PATH = _REPO_ROOT / "BENCH_executor.json"

WORKER_COUNTS = (1, 3, 8)
RAM_CAP = 512 * 1024
TRANSPORT_MODES = ("neuron", "spatial")
# the mixed section covers the acceptance regime: 7/8-worker heterogeneous
# demo clusters are where per-block mixing beats the best uniform plan
MIXED_WORKER_COUNTS = (3, 7, 8)


def _configs(quick: bool):
    from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke
    cfgs = [("smoke", mobilenet_v2_smoke)]
    if not quick:
        cfgs.append(("mnv2_112", mobilenet_v2_paper))
    return cfgs


def planner_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    from repro.api import Cluster, InfeasibleError, Objective, Planner

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    for name, make_model in _configs(quick):
        model = make_model()
        for k in WORKER_COUNTS:
            cluster = Cluster.heterogeneous_demo(k)
            planner = Planner(model, cluster)
            objective = Objective(minimize="latency", ram_cap_bytes=RAM_CAP)
            t0 = time.perf_counter()
            try:
                plan = planner.plan(objective)
            except InfeasibleError as e:
                wall = time.perf_counter() - t0
                # the search still costs wall time; record the outcome so a
                # feasibility flip vs baseline is visible in the artifact
                data[f"{name}@{k}"] = dict(feasible=False, wall_s=round(wall, 4),
                                           binding=e.binding_constraint)
                rows.append((f"planner_{name}_w{k}", wall,
                             f"INFEASIBLE ({e.binding_constraint})"))
                continue
            wall = time.perf_counter() - t0
            data[f"{name}@{k}"] = dict(
                feasible=True, wall_s=round(wall, 4),
                plan_latency_s=round(plan.latency_s, 9),
                max_peak_ram=int(plan.max_peak_ram),
                mode=plan.mode, fusion=plan.fusion,
                transport=plan.transport,
                overlap_saved_s=round(plan.overlap_saved_s, 9),
                n_workers=plan.n_workers)
            rows.append((f"planner_{name}_w{k}", wall,
                         f"mode={plan.mode}/{plan.fusion} "
                         f"transport={plan.transport} "
                         f"workers={plan.n_workers} "
                         f"latency={plan.latency_s:.4f}s "
                         f"peak={plan.max_peak_ram / 1024:.0f}KB"))
    return rows, data


def transport_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    """Deterministic async-transport rows: serial (Eq. 5-6) total vs
    pipelined makespan for the heterogeneous demo cluster, per mode.  All
    analytic — gated by ``check_regression.py``'s ``transport`` section."""
    import dataclasses

    from repro.api import Cluster
    from repro.core import SimConfig, simulate, split_model

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    cfg = SimConfig()
    for name, make_model in _configs(quick):
        model = make_model()
        for k in WORKER_COUNTS:
            if k < 2:
                continue        # single link: the transports coincide
            workers = list(Cluster.heterogeneous_demo(k).workers)
            for mode in TRANSPORT_MODES:
                plan = split_model(model, np.ones(k), mode=mode)
                serial = simulate(model, workers, cfg=cfg, plan=plan)
                piped = simulate(
                    model, workers,
                    cfg=dataclasses.replace(cfg, transport="pipelined"),
                    plan=plan)
                key = f"{name}@{k}/{mode}"
                data[key] = dict(
                    serial_s=round(serial.total_time, 9),
                    pipelined_s=round(piped.total_time, 9),
                    overlap_saved_s=round(piped.overlap_saved_s, 9),
                    mean_link_utilization=round(
                        float(piped.timeline.link_utilization.mean()), 6),
                    max_idle_s=round(float(piped.timeline.idle_s.max()), 9))
                rows.append((f"transport_{name}_w{k}_{mode}",
                             piped.total_time,
                             f"serial={serial.total_time:.4f}s "
                             f"saved={piped.overlap_saved_s:.4f}s"))
    return rows, data


def mixed_metrics(quick: bool = False) -> tuple[list[tuple], dict]:
    """Deterministic mode-mixing rows: one latency search per config@k with
    the DP-mixed axis enabled; the best *uniform* candidate and the chosen
    plan both come from that single candidate table, so the comparison is
    internally consistent.  The chosen score can never exceed the best
    uniform score (the winner is the min over a superset) — gated as an
    invariant by ``check_regression.py``'s ``mixed`` section."""
    from repro.api import (Cluster, InfeasibleError, Objective, Planner,
                           SEARCH_MODES)

    rows: list[tuple] = []
    data: dict[str, dict] = {}
    for name, make_model in _configs(quick):
        model = make_model()
        for k in MIXED_WORKER_COUNTS:
            cluster = Cluster.heterogeneous_demo(k)
            planner = Planner(model, cluster)
            objective = Objective(minimize="latency", ram_cap_bytes=RAM_CAP,
                                  modes=SEARCH_MODES)
            t0 = time.perf_counter()
            try:
                plan = planner.plan(objective)
            except InfeasibleError as e:
                wall = time.perf_counter() - t0
                data[f"{name}@{k}"] = dict(feasible=False,
                                           wall_s=round(wall, 4),
                                           binding=e.binding_constraint)
                rows.append((f"mixed_{name}_w{k}", wall,
                             f"INFEASIBLE ({e.binding_constraint})"))
                continue
            wall = time.perf_counter() - t0
            uniform = [c for c in plan.candidates
                       if c.feasible and c.mode != "mixed"]
            entry = dict(
                feasible=True, wall_s=round(wall, 4),
                mixed_s=round(plan.score, 9),
                mode=plan.mode, transport=plan.transport,
                max_peak_ram=int(plan.max_peak_ram),
                n_workers=plan.n_workers)
            # only a mixed assignment may fit where no uniform plan does
            # (mixing strictly widens feasibility); the gate's metric and
            # invariant checks both tolerate the missing key
            tag = "no feasible uniform"
            if uniform:
                best_uniform_s = min(c.score for c in uniform)
                entry["best_uniform_s"] = round(best_uniform_s, 9)
                tag = f"best_uniform={best_uniform_s:.4f}s"
            if plan.assignment is not None:
                entry["assignment"] = list(plan.assignment)
            data[f"{name}@{k}"] = entry
            rows.append((f"mixed_{name}_w{k}", plan.latency_s,
                         f"mode={plan.mode} {tag} "
                         f"chosen={plan.score:.4f}s"))
    return rows, data


def analytic_peaks(quick: bool = False) -> dict:
    """The ``peaks`` section via the same :func:`executor_bench.peaks_for`
    the timed bench uses — here so the analytic-only CI cell can refresh it
    without running any timed benchmark."""
    return {name: peaks_for(make_model())
            for name, make_model in _configs(quick)}


def merge_results(planner: dict, transport: dict, mixed: dict,
                  peaks: dict) -> dict:
    """Read-modify-write the shared JSON: update only our sections, and
    merge each of them per key — a ``--quick`` run refreshes the smoke
    entries without erasing the committed full-model (mnv2_112) coverage
    the analytic CI gate compares against."""
    payload: dict = {}
    if RESULT_PATH.exists():
        try:
            payload = json.loads(RESULT_PATH.read_text())
        except json.JSONDecodeError:
            payload = {}
    payload.setdefault("benchmark", "executor_eager_vs_compiled")
    for section, fresh in (("planner", planner), ("transport", transport),
                           ("mixed", mixed), ("peaks", peaks)):
        merged = dict(payload.get(section, {}))
        merged.update(fresh)
        payload[section] = merged
    RESULT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def _collect(quick: bool) -> tuple[list[tuple], dict]:
    rows, planner = planner_metrics(quick=quick)
    t_rows, transport = transport_metrics(quick=quick)
    m_rows, mixed = mixed_metrics(quick=quick)
    peaks = analytic_peaks(quick=quick)
    payload = merge_results(planner, transport, mixed, peaks)
    return rows + t_rows + m_rows, payload


def bench_planner(quick: bool = False) -> list[tuple]:
    """run.py suite entry: benchmark, merge JSON, return CSV rows."""
    rows, _ = _collect(quick)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke model only (CI)")
    args = ap.parse_args()
    _, payload = _collect(args.quick)
    print(json.dumps({k: payload[k]
                      for k in ("planner", "transport", "mixed")},
                     indent=2))


if __name__ == "__main__":
    main()
