"""CI benchmark-regression gate: compare a freshly produced
``BENCH_executor.json`` against the committed baseline and fail (exit 1) on
a >20% regression:

* ``speedup`` (compiled vs eager) — timing-based and noisy per row even with
  best-of-N, so the 20% line is held on the **geometric mean** across all
  overlapping {config, split, mode, batch} rows (a real engine regression
  drags every row; single-row wobble does not).  Any single row collapsing
  below half its baseline fails outright — that is a lost fast path, not
  noise.
* ``peaks`` (analytic max per-worker peak RAM per partitioning mode) —
  deterministic, so each entry growing beyond 20% is a real memory
  regression.
* ``planner`` (plan-search outcomes per {config}@{workers}) — the chosen
  plan's simulated latency and max peak RAM come from the analytic models,
  so they are deterministic too: a >20% growth means the search now picks a
  worse plan.  The recorded wall time is informational only (machine-bound).
* ``transport`` (async-transport rows per {config}@{workers}/{mode}) —
  serial total and pipelined makespan are both analytic: either growing
  >20% is a cost-model regression, and a pipelined makespan exceeding its
  serial total breaks the overlap invariant outright.
* ``mixed`` (mode-mixing rows per {config}@{workers}) — the best uniform
  candidate's score and the score of the plan chosen with the DP-mixed axis
  enabled are analytic: either growing >20% is a regression, and a chosen
  score exceeding the best uniform score breaks the mixing invariant
  outright (enabling mixing may never yield a worse plan — the winner is
  the min over a superset of the uniform candidates).
* ``runtime`` (distributed coordinator + socket workers per
  {config}@{workers}) — the two machine-independent invariants are gated on
  the FRESH rows alone: ``bitexact`` (distributed output equals the
  single-process Session bytes) and ``edges_superset`` (the measured event
  timeline realizes every dependency edge the pipelined simulator
  predicts).  ``setup_s`` / ``request_s`` / ``ratio`` are runner wall-clock
  and only reported.
* ``serving`` (multi-tenant continuous-batching server per config) — the
  machine-independent invariants gated on the FRESH rows alone:
  ``continuous_batches <= flush_batches`` (fewer, fuller dispatches for the
  same requests — the structural property of batch formation, on every
  row), ``batching_gain >= 1.0`` on rows with ``gain_gated`` (the
  continuous scheduler must serve the same concurrent client population at
  least as fast as the flush-barrier ``Session`` baseline measured
  interleaved in the same process — it wins by forming full bucket-padded
  batches where client-driven flushes dispatch ragged ones; heavy-model
  configs where per-sample compute dwarfs dispatch overhead sit at parity
  and report the gain ungated), ``bitexact`` (every request through the
  running server equals ``Session.run`` bitwise), ``overload_rejection_rate
  > 0`` (at 2x saturation offered load admission control must shed, never
  queue unboundedly) and ``overload_accepted_p99_s <= p99_bound_s`` (the
  accepted population's tail stays bounded near the SLO target; the bound
  is recorded in the row).  The rps and percentile fields are runner
  wall-clock and only reported.
* ``elastic`` (churn recovery per {config}@{workers}) — the
  machine-independent invariants gated on the FRESH rows alone:
  ``bitexact_after_recovery`` (every phase of the kill/rejoin churn loop
  equals the single-process Session on the surviving topology),
  ``reshipped_bytes < full_setup_bytes`` (the plan diff must beat a cold
  re-setup — delta shipping is the point of the replan layer),
  ``cache_hit_rate == 1.0`` whenever ``expected_cache_hits`` > 0 (every
  unchanged shard geometry must hit the worker's warm compiled cache),
  and ``leaked_tasks == 0`` (no orphaned asyncio tasks after shutdown).
  ``downtime_kill_s`` / ``downtime_rejoin_s`` are runner wall-clock and
  only reported.  ``--analytic`` rows (plan-diff only, no live workers)
  carry just the reship invariant — the pinned-min cell gates those.
* ``search`` (plan-search rows per {config}@{workers}) — the analytic
  scores (``ladder_score``, ``beam_score``, ``dp_transport_pipelined_s``)
  drift-gate at the 20% line; four machine-independent invariants hold on
  the FRESH rows alone: ``beam_score <= ladder_score`` (the beam evaluates
  every ladder prefix, so its plan may never be worse),
  ``warm_misses < cold_replan_misses`` (a warm-cache replan must *evaluate*
  strictly fewer candidates than a cold search of the same survivor
  topology), ``warm_hit_rate > 0`` (the replan actually reused cached
  evaluations), and ``dp_transport_pipelined_s <= dp_serial_pipelined_s``
  with a strict win (``transport_dp_win``) required on at least one
  mnv2_112 row whenever mnv2_112 rows are fresh — the transport-aware
  mixing DP must beat the serial surrogate where heterogeneity bites.
  The ``*_wall_s`` fields are runner wall-clock and only reported.
* ``kernels`` (per-kernel ref-vs-Pallas micro-bench) — ``speedup`` is a
  ratio of two paths timed in the same process, so it is machine-insensitive
  even though the absolute wall times are not: the 20% line is held on the
  geometric mean across overlapping kernels, any single kernel collapsing
  below half its baseline fails outright.  This section also holds the
  hot-path invariant on the FRESH rows: every spatial int8 executor row must
  show compiled beating eager (speedup >= 1.0) — the fused batched-band
  schedule exists to win that race at every batch size, and losing it is a
  regression regardless of what the baseline said.

``--sections`` restricts which sections are compared — the pinned-min jax
CI cell regenerates only the analytic + ratio sections
(``peaks,planner,transport,mixed,search,kernels``) and gates those,
catching cost-model drift the latest-jax bench job can mask.

Rows/modes present in only one file are reported but don't fail the gate
(benchmarks may gain coverage); missing files or empty overlap DO fail — a
gate that silently compares nothing holds no line.

Run:  python benchmarks/check_regression.py --baseline BENCH_executor.json \
          --fresh fresh/BENCH_executor.json [--threshold 0.2] \
          [--sections rows,peaks,planner,transport]
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys


def _row_key(row: dict) -> tuple:
    # older baselines predate the 'split' field — treat them as neuron-mode
    return (row["config"], row.get("split", "neuron"), row["mode"],
            row["batch"])


SECTIONS = ("rows", "peaks", "planner", "transport", "mixed", "kernels",
            "runtime", "serving", "elastic", "search")


def compare(baseline: dict, fresh: dict, threshold: float,
            sections: tuple[str, ...] = SECTIONS) -> tuple[list[str], int]:
    """Returns (failure messages, number of metrics actually compared)."""
    failures: list[str] = []
    compared = 0
    base_rows = {_row_key(r): r for r in baseline.get("rows", [])
                 if "rows" in sections}
    fresh_rows = {_row_key(r): r for r in fresh.get("rows", [])
                  if "rows" in sections}
    ratios = []
    for key in sorted(base_rows.keys() & fresh_rows.keys()):
        b, f = base_rows[key]["speedup"], fresh_rows[key]["speedup"]
        compared += 1
        tag = "/".join(str(k) for k in key)
        ratio = f / b if b > 0 else 1.0
        ratios.append(ratio)
        print(f"speedup {tag}: {f:.2f}x (baseline {b:.2f}x, {ratio:.0%})")
        if ratio < 0.5:
            failures.append(
                f"speedup collapse {tag}: {f:.2f}x is below half of "
                f"baseline {b:.2f}x — a lost fast path, not noise")
    if ratios:
        geomean = math.exp(sum(math.log(max(r, 1e-9)) for r in ratios)
                           / len(ratios))
        line = (f"geomean speedup ratio over {len(ratios)} rows: "
                f"{geomean:.0%} of baseline")
        if geomean < 1.0 - threshold:
            failures.append(f"{line} (allowed: {1.0 - threshold:.0%})")
        else:
            print(f"ok {line}")
    for key in sorted(base_rows.keys() ^ fresh_rows.keys()):
        print(f"note: row {key} present in only one file — skipped")
    base_peaks = baseline.get("peaks", {}) if "peaks" in sections else {}
    fresh_peaks = fresh.get("peaks", {}) if "peaks" in sections else {}
    for config in sorted(base_peaks.keys() & fresh_peaks.keys()):
        for mode in sorted(base_peaks[config].keys()
                           & fresh_peaks[config].keys()):
            b, f = base_peaks[config][mode], fresh_peaks[config][mode]
            compared += 1
            if f > b * (1.0 + threshold):
                failures.append(
                    f"peak-RAM regression {config}/{mode}: "
                    f"{f} B > {1.0 + threshold:.0%} of baseline {b} B")
            else:
                print(f"ok peak {config}/{mode}: {f} B (baseline {b} B)")
    base_planner = baseline.get("planner", {}) if "planner" in sections else {}
    fresh_planner = fresh.get("planner", {}) if "planner" in sections else {}
    for key in sorted(base_planner.keys() & fresh_planner.keys()):
        b, f = base_planner[key], fresh_planner[key]
        if b.get("feasible") != f.get("feasible"):
            compared += 1
            failures.append(
                f"planner feasibility flip {key}: baseline "
                f"feasible={b.get('feasible')} vs fresh "
                f"feasible={f.get('feasible')}")
            continue
        for metric in ("plan_latency_s", "max_peak_ram"):
            if metric not in b or metric not in f:
                continue
            compared += 1
            if f[metric] > b[metric] * (1.0 + threshold):
                failures.append(
                    f"planner regression {key}/{metric}: {f[metric]} > "
                    f"{1.0 + threshold:.0%} of baseline {b[metric]}")
            else:
                print(f"ok planner {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]})")
    base_tp = baseline.get("transport", {}) if "transport" in sections else {}
    fresh_tp = fresh.get("transport", {}) if "transport" in sections else {}
    for key in sorted(base_tp.keys() & fresh_tp.keys()):
        b, f = base_tp[key], fresh_tp[key]
        for metric in ("serial_s", "pipelined_s"):
            if metric not in b or metric not in f:
                continue
            compared += 1
            if f[metric] > b[metric] * (1.0 + threshold):
                failures.append(
                    f"transport regression {key}/{metric}: {f[metric]} > "
                    f"{1.0 + threshold:.0%} of baseline {b[metric]}")
            else:
                print(f"ok transport {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]})")
        # the overlap invariant is machine-independent: pipelined may never
        # be slower than the serial schedule it relaxes
        if ("serial_s" in f and "pipelined_s" in f
                and f["pipelined_s"] > f["serial_s"] * (1.0 + 1e-9)):
            compared += 1
            failures.append(
                f"transport invariant broken {key}: pipelined "
                f"{f['pipelined_s']} s exceeds serial {f['serial_s']} s")
    base_mx = baseline.get("mixed", {}) if "mixed" in sections else {}
    fresh_mx = fresh.get("mixed", {}) if "mixed" in sections else {}
    for key in sorted(base_mx.keys() & fresh_mx.keys()):
        b, f = base_mx[key], fresh_mx[key]
        if b.get("feasible") != f.get("feasible"):
            compared += 1
            failures.append(
                f"mixed feasibility flip {key}: baseline "
                f"feasible={b.get('feasible')} vs fresh "
                f"feasible={f.get('feasible')}")
            continue
        for metric in ("best_uniform_s", "mixed_s", "max_peak_ram"):
            if metric not in b or metric not in f:
                continue
            compared += 1
            if f[metric] > b[metric] * (1.0 + threshold):
                failures.append(
                    f"mixed regression {key}/{metric}: {f[metric]} > "
                    f"{1.0 + threshold:.0%} of baseline {b[metric]}")
            else:
                print(f"ok mixed {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]})")
    for key in sorted(fresh_mx.keys()):
        f = fresh_mx[key]
        # machine-independent: enabling the mixed axis may never pick a
        # plan scoring worse than the best uniform candidate of the same
        # search (the winner is a min over a superset)
        if ("best_uniform_s" in f and "mixed_s" in f
                and f["mixed_s"] > f["best_uniform_s"] * (1.0 + 1e-9)):
            compared += 1
            failures.append(
                f"mixed invariant broken {key}: chosen score "
                f"{f['mixed_s']} exceeds best uniform "
                f"{f['best_uniform_s']}")
    base_sr = baseline.get("search", {}) if "search" in sections else {}
    fresh_sr = fresh.get("search", {}) if "search" in sections else {}
    for key in sorted(base_sr.keys() & fresh_sr.keys()):
        b, f = base_sr[key], fresh_sr[key]
        # the scores are analytic: growth past the threshold means the
        # search now finds a worse plan, not machine noise
        for metric in ("ladder_score", "beam_score",
                       "dp_transport_pipelined_s"):
            if metric not in b or metric not in f:
                continue
            compared += 1
            if f[metric] > b[metric] * (1.0 + threshold):
                failures.append(
                    f"search regression {key}/{metric}: {f[metric]} > "
                    f"{1.0 + threshold:.0%} of baseline {b[metric]}")
            else:
                print(f"ok search {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]})")
    transport_dp_wins = []
    fresh_mnv2 = [k for k in fresh_sr if k.startswith("mnv2_112@")]
    for key in sorted(fresh_sr.keys()):
        f = fresh_sr[key]
        # all four invariants are machine-independent — gated on the fresh
        # rows alone
        if ("beam_score" in f and "ladder_score" in f):
            compared += 1
            if f["beam_score"] > f["ladder_score"] * (1.0 + 1e-9):
                failures.append(
                    f"search invariant broken {key}: beam plan score "
                    f"{f['beam_score']} exceeds ladder plan score "
                    f"{f['ladder_score']} — the beam evaluates every "
                    f"ladder prefix, so it may never be worse")
            else:
                print(f"ok search {key}/beam<=ladder: {f['beam_score']} "
                      f"<= {f['ladder_score']}")
        if ("warm_misses" in f and "cold_replan_misses" in f):
            compared += 1
            if f["warm_misses"] >= f["cold_replan_misses"]:
                failures.append(
                    f"search invariant broken {key}: warm replan evaluated "
                    f"{f['warm_misses']} candidates, not strictly fewer "
                    f"than the cold search's {f['cold_replan_misses']}")
            else:
                print(f"ok search {key}/warm<cold: {f['warm_misses']} < "
                      f"{f['cold_replan_misses']} evaluations")
        if "warm_hit_rate" in f:
            compared += 1
            if f["warm_hit_rate"] <= 0.0:
                failures.append(
                    f"search invariant broken {key}: warm replan hit rate "
                    f"{f['warm_hit_rate']} — the cache reused nothing")
            else:
                print(f"ok search {key}/warm_hit_rate: "
                      f"{f['warm_hit_rate']}")
        if ("dp_serial_pipelined_s" in f and "dp_transport_pipelined_s" in f):
            compared += 1
            if (f["dp_transport_pipelined_s"]
                    > f["dp_serial_pipelined_s"] * (1.0 + 1e-9)):
                failures.append(
                    f"search invariant broken {key}: transport-aware DP "
                    f"pipelined latency {f['dp_transport_pipelined_s']} s "
                    f"exceeds the serial-surrogate DP's "
                    f"{f['dp_serial_pipelined_s']} s — the re-rank makes "
                    f"this impossible unless the variant set shrank")
            else:
                print(f"ok search {key}/dp_transport<=dp_serial: "
                      f"{f['dp_transport_pipelined_s']} <= "
                      f"{f['dp_serial_pipelined_s']}")
            if key in fresh_mnv2 and f.get("transport_dp_win"):
                transport_dp_wins.append(key)
    if fresh_mnv2:
        compared += 1
        if not transport_dp_wins:
            failures.append(
                "search invariant broken: no fresh mnv2_112 row shows the "
                "transport-aware mixing DP strictly beating the serial "
                "surrogate on pipelined latency (transport_dp_win)")
        else:
            print(f"ok search transport_dp_win on {transport_dp_wins}")
    base_kn = baseline.get("kernels", {}) if "kernels" in sections else {}
    fresh_kn = fresh.get("kernels", {}) if "kernels" in sections else {}
    kn_ratios = []
    for key in sorted(base_kn.keys() & fresh_kn.keys()):
        b, f = base_kn[key].get("speedup"), fresh_kn[key].get("speedup")
        if b is None or f is None:
            continue
        compared += 1
        ratio = f / b if b > 0 else 1.0
        kn_ratios.append(ratio)
        print(f"kernel {key}: {f:.3f}x (baseline {b:.3f}x, {ratio:.0%})")
        if ratio < 0.5:
            failures.append(
                f"kernel speedup collapse {key}: {f:.3f}x is below half "
                f"of baseline {b:.3f}x — a lost kernel path, not noise")
    if kn_ratios:
        geomean = math.exp(sum(math.log(max(r, 1e-9)) for r in kn_ratios)
                           / len(kn_ratios))
        line = (f"geomean kernel speedup ratio over {len(kn_ratios)} "
                f"kernels: {geomean:.0%} of baseline")
        if geomean < 1.0 - threshold:
            failures.append(f"{line} (allowed: {1.0 - threshold:.0%})")
        else:
            print(f"ok {line}")
    base_rt = baseline.get("runtime", {}) if "runtime" in sections else {}
    fresh_rt = fresh.get("runtime", {}) if "runtime" in sections else {}
    for key in sorted(fresh_rt.keys()):
        f = fresh_rt[key]
        # both machine-independent: distributed output must equal the
        # single-process Session bytes, and the measured event timeline must
        # realize every dependency edge the pipelined simulator predicts
        for inv in ("bitexact", "edges_superset"):
            if inv not in f:
                continue
            compared += 1
            if not f[inv]:
                failures.append(
                    f"runtime invariant broken {key}: {inv} is False — the "
                    f"distributed runtime diverged from the "
                    f"{'Session output' if inv == 'bitexact' else 'pipelined schedule'}")
            else:
                print(f"ok runtime {key}/{inv}")
    for key in sorted(base_rt.keys() & fresh_rt.keys()):
        b, f = base_rt[key], fresh_rt[key]
        for metric in ("setup_s", "request_s", "ratio"):
            if metric in b and metric in f:
                # wall-clock on the CI runner: informational only
                print(f"note runtime {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]}, not gated)")
    base_sv = baseline.get("serving", {}) if "serving" in sections else {}
    fresh_sv = fresh.get("serving", {}) if "serving" in sections else {}
    for key in sorted(fresh_sv.keys()):
        f = fresh_sv[key]
        # all four serving invariants are machine-independent and gated on
        # the fresh rows alone (rps/percentile magnitudes are runner-bound)
        if "continuous_batches" in f and "flush_batches" in f:
            compared += 1
            if f["continuous_batches"] > f["flush_batches"]:
                failures.append(
                    f"serving invariant broken {key}: the continuous "
                    f"scheduler used {f['continuous_batches']} dispatches "
                    f"where the flush-barrier baseline used "
                    f"{f['flush_batches']} for the same requests — batch "
                    f"formation is not consolidating work")
            else:
                print(f"ok serving {key}/dispatch_count: "
                      f"{f['continuous_batches']} <= {f['flush_batches']}")
        if "batching_gain" in f and f.get("gain_gated", True):
            compared += 1
            if f["batching_gain"] < 1.0:
                failures.append(
                    f"serving invariant broken {key}: continuous batching is "
                    f"{f['batching_gain']:.3f}x the flush-barrier Session "
                    f"baseline — the scheduler must at least match the "
                    f"barrier path it replaces "
                    f"({f.get('continuous_batches')} vs "
                    f"{f.get('flush_batches')} dispatches)")
            else:
                print(f"ok serving {key}/batching_gain: "
                      f"{f['batching_gain']:.3f}x >= 1.0")
        elif "batching_gain" in f:
            # heavy-model configs: per-sample compute dwarfs dispatch
            # overhead, so throughput sits at parity and only the dispatch-
            # count invariant above is structural
            print(f"note serving {key}/batching_gain: "
                  f"{f['batching_gain']:.3f}x (not gated for this config)")
        if "bitexact" in f:
            compared += 1
            if not f["bitexact"]:
                failures.append(
                    f"serving invariant broken {key}: bitexact is False — "
                    f"served outputs diverged from Session.run")
            else:
                print(f"ok serving {key}/bitexact")
        if "overload_rejection_rate" in f:
            compared += 1
            if not f["overload_rejection_rate"] > 0:
                failures.append(
                    f"serving invariant broken {key}: zero rejections at "
                    f"{f.get('overload_offered_rps')} rps offered "
                    f"(2x saturation) — admission control is not shedding")
            else:
                print(f"ok serving {key}/overload_rejection_rate: "
                      f"{f['overload_rejection_rate']:.1%} > 0")
        if "overload_accepted_p99_s" in f and "p99_bound_s" in f:
            compared += 1
            if f["overload_accepted_p99_s"] > f["p99_bound_s"]:
                failures.append(
                    f"serving invariant broken {key}: accepted-request p99 "
                    f"{f['overload_accepted_p99_s']} s exceeds the bound "
                    f"{f['p99_bound_s']} s under overload — admission "
                    f"control failed to keep the accepted tail bounded")
            else:
                print(f"ok serving {key}/overload_accepted_p99_s: "
                      f"{f['overload_accepted_p99_s']} s <= "
                      f"{f['p99_bound_s']} s")
    for key in sorted(base_sv.keys() & fresh_sv.keys()):
        b, f = base_sv[key], fresh_sv[key]
        for metric in ("continuous_rps", "flush_rps", "saturation_rps",
                       "steady_a_p99_s", "steady_b_p99_s"):
            if metric in b and metric in f:
                # wall-clock on the CI runner: informational only
                print(f"note serving {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]}, not gated)")
    base_el = baseline.get("elastic", {}) if "elastic" in sections else {}
    fresh_el = fresh.get("elastic", {}) if "elastic" in sections else {}
    for key in sorted(fresh_el.keys()):
        f = fresh_el[key]
        # all elastic invariants are machine-independent and gated on the
        # fresh rows alone (downtime magnitudes are runner-bound)
        if "bitexact_after_recovery" in f:
            compared += 1
            if not f["bitexact_after_recovery"]:
                failures.append(
                    f"elastic invariant broken {key}: "
                    f"bitexact_after_recovery is False — post-churn output "
                    f"diverged from the single-process Session on the "
                    f"surviving topology")
            else:
                print(f"ok elastic {key}/bitexact_after_recovery")
        for rs, fl in (("reshipped_bytes", "full_setup_bytes"),
                       ("rejoin_reshipped_bytes",
                        "rejoin_full_setup_bytes")):
            if rs not in f or fl not in f:
                continue
            compared += 1
            if f[rs] >= f[fl]:
                failures.append(
                    f"elastic invariant broken {key}: {rs} {f[rs]} B >= "
                    f"{fl} {f[fl]} B — the plan diff re-shipped no less "
                    f"than a cold re-setup, delta shipping is dead")
            else:
                print(f"ok elastic {key}/{rs}: {f[rs]} B < {f[fl]} B")
        if f.get("expected_cache_hits", 0) > 0 and "cache_hit_rate" in f:
            compared += 1
            if f["cache_hit_rate"] != 1.0:
                failures.append(
                    f"elastic invariant broken {key}: cache_hit_rate "
                    f"{f['cache_hit_rate']} != 1.0 over "
                    f"{f['expected_cache_hits']} unchanged geometries — a "
                    f"warm recompile missed the compiled-segment cache")
            else:
                print(f"ok elastic {key}/cache_hit_rate: 1.0 over "
                      f"{f['expected_cache_hits']} unchanged geometries")
        if "leaked_tasks" in f:
            compared += 1
            if f["leaked_tasks"] != 0:
                failures.append(
                    f"elastic invariant broken {key}: {f['leaked_tasks']} "
                    f"asyncio task(s) leaked after close()")
            else:
                print(f"ok elastic {key}/leaked_tasks: 0")
    for key in sorted(base_el.keys() & fresh_el.keys()):
        b, f = base_el[key], fresh_el[key]
        for metric in ("downtime_kill_s", "downtime_rejoin_s"):
            if metric in b and metric in f:
                # wall-clock on the CI runner: informational only
                print(f"note elastic {key}/{metric}: {f[metric]} "
                      f"(baseline {b[metric]}, not gated)")
    if "kernels" in sections:
        # machine-independent hot-path invariant on the fresh executor rows:
        # compiled spatial int8 must beat eager at every benched batch size
        for row in fresh.get("rows", []):
            if row.get("split") != "spatial" or row.get("mode") != "int8":
                continue
            compared += 1
            tag = f"{row['config']}/spatial/int8/b{row['batch']}"
            if row["speedup"] < 1.0:
                failures.append(
                    f"hot-path invariant broken {tag}: compiled spatial "
                    f"int8 is {row['speedup']:.2f}x vs eager — the fused "
                    f"band schedule must win at every batch size")
            else:
                print(f"ok hot-path {tag}: {row['speedup']:.2f}x >= 1.0")
    return failures, compared


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, type=pathlib.Path,
                    help="committed BENCH_executor.json")
    ap.add_argument("--fresh", required=True, type=pathlib.Path,
                    help="freshly produced BENCH_executor.json")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="allowed fractional regression (default 0.20)")
    ap.add_argument("--sections", default=",".join(SECTIONS),
                    help="comma-separated sections to compare "
                         f"(default: {','.join(SECTIONS)})")
    args = ap.parse_args(argv)
    sections = tuple(s.strip() for s in args.sections.split(",") if s.strip())
    for s in sections:
        if s not in SECTIONS:
            print(f"FAIL: unknown section {s!r} (want one of {SECTIONS})")
            return 1
    try:
        baseline = json.loads(args.baseline.read_text())
        fresh = json.loads(args.fresh.read_text())
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL: cannot load benchmark JSON: {e}")
        return 1
    failures, compared = compare(baseline, fresh, args.threshold, sections)
    if compared == 0:
        print("FAIL: no overlapping benchmark metrics to compare")
        return 1
    if failures:
        for msg in failures:
            print(f"FAIL: {msg}")
        return 1
    print(f"benchmark gate passed: {compared} metrics within "
          f"{args.threshold:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
