"""Benchmark driver: one function per paper table/figure + kernel bench +
the executor engine bench (which also writes BENCH_executor.json).
Prints ``name,value,derived`` CSV.

Run:  PYTHONPATH=src python -m benchmarks.run [--smoke]
  or  PYTHONPATH=src python benchmarks/run.py [--smoke]

``--smoke`` (or REPRO_BENCH_QUICK=1) restricts the executor bench to the
smoke config — the CI invocation.  Exits non-zero if ANY sub-benchmark
raises: a failed suite prints an ``<title>,ERROR,...`` row, the remaining
suites still run, and the failure is reported at exit so CI cannot go green
on partial results.
"""
from __future__ import annotations

import argparse
import functools
import os
import sys
import time


def build_suites(quick: bool):
    try:
        from . import (elastic_bench, executor_bench, kernel_bench,
                       paper_benchmarks as pb, planner_bench,
                       roofline_report, runtime_bench, serving_bench)
    except ImportError:  # run as a plain script: benchmarks/ is sys.path[0]
        import elastic_bench
        import executor_bench, kernel_bench, planner_bench  # noqa: E401
        import paper_benchmarks as pb
        import roofline_report
        import runtime_bench
        import serving_bench
    return [
        ("Table I (K1 calibration)", pb.table1_k1),
        ("Table II (allocation strategies)", pb.table2_allocation),
        ("Fig 8 (layer-wise peak RAM)", pb.fig8_layer_peak_ram),
        ("Fig 9 (latency scaling)", pb.fig9_latency_scaling),
        ("Figs 10-11 (layer-wise comm/comp)", pb.fig10_fig11_layerwise),
        ("Fig 12 (memory scalability)", pb.fig12_scalability),
        ("Partitioning modes (comm/peak tradeoff)", pb.mode_tradeoff),
        ("Kernels", kernel_bench.bench_kernels),
        ("Executor (eager vs compiled)",
         functools.partial(executor_bench.bench_executor, quick=quick)),
        ("Planner (plan-search)",
         functools.partial(planner_bench.bench_planner, quick=quick)),
        ("Runtime (distributed coordinator)",
         functools.partial(runtime_bench.bench_runtime, quick=quick)),
        ("Serving (multi-tenant continuous batching)",
         functools.partial(serving_bench.bench_serving, quick=quick)),
        ("Elastic (churn recovery)",
         functools.partial(elastic_bench.bench_elastic, quick=quick)),
        # last: renders the roofline/compile sections the executor bench
        # just persisted into roofline_report.md (uploaded by CI)
        ("Roofline (per-block report)", roofline_report.bench_roofline),
    ]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smoke configs only (CI; same as REPRO_BENCH_QUICK=1)")
    args = ap.parse_args(argv)
    quick = args.smoke or os.environ.get(
        "REPRO_BENCH_QUICK", "") not in ("", "0", "false", "False")
    print("name,value,derived")
    failed: list[str] = []
    for title, fn in build_suites(quick):
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{title},ERROR,{type(e).__name__}: {e}")
            failed.append(title)
            continue
        for name, value, derived in rows:
            if isinstance(value, float):
                value = f"{value:.4f}"
            print(f"{name},{value},{derived}")
        print(f"# {title}: {time.time()-t0:.1f}s", file=sys.stderr)
    if failed:
        print(f"# FAILED suites: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
