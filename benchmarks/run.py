"""Benchmark driver: one function per paper table/figure + kernel bench +
the executor engine bench (which also writes BENCH_executor.json).
Prints ``name,value,derived`` CSV (run: PYTHONPATH=src python -m benchmarks.run).
Set REPRO_BENCH_QUICK=1 to restrict the executor bench to the smoke config
(the CI smoke invocation).
"""
from __future__ import annotations

import functools
import os
import sys
import time


def main() -> None:
    from . import executor_bench, kernel_bench, paper_benchmarks as pb
    quick = os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0", "false",
                                                            "False")
    suites = [
        ("Table I (K1 calibration)", pb.table1_k1),
        ("Table II (allocation strategies)", pb.table2_allocation),
        ("Fig 8 (layer-wise peak RAM)", pb.fig8_layer_peak_ram),
        ("Fig 9 (latency scaling)", pb.fig9_latency_scaling),
        ("Figs 10-11 (layer-wise comm/comp)", pb.fig10_fig11_layerwise),
        ("Fig 12 (memory scalability)", pb.fig12_scalability),
        ("Kernels", kernel_bench.bench_kernels),
        ("Executor (eager vs compiled)",
         functools.partial(executor_bench.bench_executor, quick=quick)),
    ]
    print("name,value,derived")
    failures = 0
    for title, fn in suites:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"{title},ERROR,{type(e).__name__}: {e}")
            failures += 1
            continue
        for name, value, derived in rows:
            if isinstance(value, float):
                value = f"{value:.4f}"
            print(f"{name},{value},{derived}")
        print(f"# {title}: {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
