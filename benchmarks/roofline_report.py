"""Render the per-block roofline report for the compiled spatial int8 path.

Reads the ``roofline`` section of ``BENCH_executor.json`` (written by
``executor_bench`` — per fused block: wall time, analytic MACs, achieved
GFLOP/s and the fraction of this host's measured dense-matmul peak) and
prints a markdown report.  CI uploads the rendered report as a workflow
artifact; locally it is the first place to look when a block underperforms.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_report [BENCH_executor.json]
  (or via the suite: python -m benchmarks.run --suites roofline)
"""
from __future__ import annotations

import json
import pathlib
import sys

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_PATH = _REPO_ROOT / "BENCH_executor.json"


def load(path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def _block_items(entries: dict) -> list[tuple[str, dict]]:
    return sorted((k, v) for k, v in entries.items() if not k.startswith("_"))


def config_table(entries: dict) -> str:
    out = ["| block | layers | wall ms | MMACs | GFLOP/s | roofline frac |",
           "|---|---|---|---|---|---|"]
    for key, e in _block_items(entries):
        layers = e["layers"]
        span = (f"L{layers[0]}" if len(layers) == 1
                else f"L{layers[0]}-L{layers[-1]}")
        out.append(f"| {key} | {span} | {e['wall_s'] * 1e3:.2f} | "
                   f"{e['macs'] / 1e6:.2f} | {e['gflops']:.2f} | "
                   f"{e['roofline_frac']:.4f} |")
    return "\n".join(out)


def report(payload: dict) -> str:
    roofline = payload.get("roofline") or {}
    lines = ["# Per-block roofline — compiled spatial int8 path", ""]
    if not roofline:
        lines.append("(no `roofline` section in BENCH_executor.json — run "
                     "`python -m benchmarks.executor_bench` first)")
        return "\n".join(lines)
    lines.append(f"backend: `{payload.get('backend', '?')}`")
    for config in sorted(roofline):
        entries = roofline[config]
        blocks = _block_items(entries)
        if not blocks:
            continue
        peak = entries.get("_peak_gflops")
        lines += ["", f"## {config}", ""]
        if peak is not None:
            lines.append(f"measured host peak (f32 matmul): "
                         f"{peak:.0f} GFLOP/s")
            lines.append("")
        lines.append(config_table(entries))
        total_wall = sum(e["wall_s"] for _, e in blocks)
        total_macs = sum(e["macs"] for _, e in blocks)
        agg = 2.0 * total_macs / total_wall / 1e9
        worst = min(blocks, key=lambda kv: kv[1]["roofline_frac"])
        lines += ["",
                  f"total spatial wall: {total_wall * 1e3:.2f} ms over "
                  f"{len(blocks)} blocks; aggregate {agg:.2f} GFLOP/s"
                  + (f" ({agg / peak:.4f} of peak)" if peak else ""),
                  f"worst block: {worst[0]} "
                  f"(frac {worst[1]['roofline_frac']:.4f})"]
    compile_sec = payload.get("compile") or {}
    if compile_sec:
        lines += ["", "## Compile cost (spatial int8, batch 1)", "",
                  "| config | cold s | cached s | cache hits/misses |",
                  "|---|---|---|---|"]
        for config in sorted(compile_sec):
            ct = compile_sec[config].get("spatial_int8_b1")
            if not ct:
                continue
            lines.append(f"| {config} | {ct['cold_s']:.3f} | "
                         f"{ct['cached_s']:.3f} | "
                         f"{ct['cache_hits']}/{ct['cache_misses']} |")
    return "\n".join(lines) + "\n"


def bench_roofline() -> list[tuple]:
    """run.py suite entry: summarize the persisted roofline section as CSV
    rows (one per config) — the full markdown goes to roofline_report.md."""
    payload = load(DEFAULT_PATH) if DEFAULT_PATH.exists() else {}
    out_path = _REPO_ROOT / "roofline_report.md"
    out_path.write_text(report(payload))
    rows = []
    for config, entries in sorted((payload.get("roofline") or {}).items()):
        blocks = _block_items(entries)
        if not blocks:
            continue
        total_wall = sum(e["wall_s"] for _, e in blocks)
        worst = min(b[1]["roofline_frac"] for b in blocks)
        rows.append((f"roofline_{config}_spatial_ms", total_wall * 1e3,
                     f"{len(blocks)} blocks, worst frac={worst:.4f}"))
    rows.append(("roofline_report_md", 1.0, str(out_path.name)))
    return rows


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT_PATH
    print(report(load(path)), end="")


if __name__ == "__main__":
    main()
