"""Render EXPERIMENTS.md tables from the dry-run JSONL results.

Usage:
  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun_single.jsonl
"""
from __future__ import annotations

import json
import sys


def _fmt_bytes(b):
    return f"{b/2**30:.2f}"


def load(path):
    with open(path) as f:
        return [json.loads(line) for line in f]


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | status | compile s | mem/dev GiB (args+temp) | collectives/dev |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped ({r['reason'][:40]}…) | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAILED | — | — | {r.get('error','')[:60]} |")
            continue
        mem = r["mem"]
        total = (mem["argument"] + mem["temp"] + mem["output"] - mem["alias"])
        coll = ", ".join(f"{k.split('-')[-1][:3]}:{v/2**30:.1f}G"
                         for k, v in sorted(r["coll_bytes"].items()) if v > 2**20)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['t_compile_s']:.0f} | {_fmt_bytes(total)} | {coll or '<1MiB'} |")
    return "\n".join(out)


def roofline_table(rows) -> str:
    out = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
           "MODEL/HLO flops | roofline frac | one-line diagnosis |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        diag = _diagnose(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.1f} | "
            f"{r['t_memory']*1e3:.1f} | {r['t_collective']*1e3:.1f} | "
            f"{r['bottleneck']} | {r['useful_flops_frac']:.2f} | "
            f"{r['roofline_frac']:.3f} | {diag} |")
    return "\n".join(out)


def _diagnose(r) -> str:
    b = r["bottleneck"]
    if r["shape"].startswith("decode") or r["shape"].startswith("long"):
        if b == "memory":
            return "cache+weight streaming bound (expected for bs-limited decode)"
        if b == "collective":
            return "per-step FSDP weight gathers dominate; widen batch or cache weights"
    if b == "memory":
        return "fusion-boundary traffic; bigger fusions / bf16 end-to-end would cut it"
    if b == "collective":
        return "SP all-gathers + dk/dv all-reduce; ring-attention or 2D sharding"
    return "compute-bound: good; push MXU utilization via kernel fusion"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_single.jsonl"
    rows = load(path)
    print("### Dry-run\n")
    print(dryrun_table(rows))
    print("\n### Roofline\n")
    print(roofline_table(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_frac"])
        coll = max(ok, key=lambda r: r["t_collective"] /
                   max(r["t_compute"] + r["t_memory"], 1e-12))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_frac']:.4f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']} "
              f"(t_coll/t_rest = "
              f"{coll['t_collective']/max(coll['t_compute']+coll['t_memory'],1e-12):.2f})")


if __name__ == "__main__":
    main()
