"""Eager vs compiled split-executor benchmark (the engine perf trajectory).

Measures wall-clock per batch for the eager reference ``SplitExecutor`` and
the jitted ``CompiledSplitExecutor`` over {config} x {split mode} x
{float, int8} x {batch 1, 8} on heterogeneous ratings, and writes the rows to
``BENCH_executor.json`` at the repo root:

    {config, split, mode, batch, eager_s, compiled_s, speedup}

plus one ``split="session"`` row per config measuring the serving facade:
``repro.api.Session`` micro-batching (``submit_many`` over bucket-padded
batches, ``compiled_s``) against per-request ``session.run()`` dispatches
(``eager_s``) — the speedup is the micro-batching amortization the ISSUE's
acceptance criterion requires to stay > 1,

plus the analytic per-worker peak-RAM maxima per partitioning mode (the
``peaks`` section — deterministic, used by the CI regression gate alongside
the speedups).  The spatial split is benchmarked on the int8 deployment path.

Compilation is excluded (one warmup per compiled entry); the eager executor
is warmed once per mode so its per-op jit caches are hot too — the measured
gap is dispatch/host-sync vs a single fused XLA computation, not compile
time.

Run:  PYTHONPATH=src python -m benchmarks.executor_bench [--quick]
(--quick: smoke config only, fewer iters — used by the CI smoke run.)
"""
from __future__ import annotations

import argparse
import functools
import json
import pathlib
import time

import numpy as np

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULT_PATH = _REPO_ROOT / "BENCH_executor.json"

BATCHES = (1, 8)
RATINGS = (3.0, 1.0, 2.0, 0.5)          # heterogeneous 4-worker cluster
PEAK_MODES = ("neuron", "kernel", "spatial")


def _configs(quick: bool):
    from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke
    # best-of-5 on the smoke config: the CI regression gate compares the
    # eager/compiled speedup ratio, so damp run-to-run timing noise
    cfgs = [("smoke", mobilenet_v2_smoke, 32, 5)]
    if not quick:
        cfgs.append(("mnv2_112", mobilenet_v2_paper, 112, 2))
    return cfgs


def _time(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def build_plans(model) -> dict:
    """One SplitPlan per peak mode on the bench's heterogeneous ratings."""
    from repro.core import split_model

    return {mode: split_model(model, np.asarray(RATINGS), mode=mode)
            for mode in PEAK_MODES}


def peaks_for(model, plans: dict | None = None) -> dict[str, int]:
    """The analytic per-mode max per-worker peak for one config — the single
    definition of the ``peaks`` section, shared with ``planner_bench`` so the
    two writers of the shared JSON cannot drift apart."""
    from repro.core import peak_ram_per_worker

    plans = plans if plans is not None else build_plans(model)
    return {mode: int(peak_ram_per_worker(plan).max())
            for mode, plan in plans.items()}


@functools.lru_cache(maxsize=1)
def _peak_gflops() -> float:
    """Measured dense-f32-matmul throughput of this host (XLA, 1024^3): the
    roofline ceiling the per-block achieved FLOP rate is reported against.
    A proxy, not a spec sheet — it is measured by the same stack that runs
    the executor, so the fraction tracks real headroom on this machine."""
    import jax
    import jax.numpy as jnp
    n = 1024
    a = jnp.ones((n, n), jnp.float32)
    b = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a, b: a @ b)
    f(a, b).block_until_ready()
    best = min(_time(lambda: f(a, b).block_until_ready(), 1)
               for _ in range(3))
    return 2.0 * n ** 3 / best / 1e9


def roofline_section(model, plan, qm, iters: int = 3) -> dict:
    """Per-fused-block wall time + achieved-vs-roofline FLOP fraction for the
    compiled spatial int8 hot path (the ``roofline`` BENCH section rendered
    by ``benchmarks/roofline_report.py``)."""
    import jax
    import jax.numpy as jnp
    from repro.core import CompiledSplitExecutor
    from repro.core.reinterpret import macs_for_positions

    ex = CompiledSplitExecutor(plan, qm)
    peak = _peak_gflops()
    entries: dict[str, dict | float] = {}
    for bi, idxs in enumerate(plan.block_groups):
        if plan.splits[idxs[0]].mode != "spatial":
            continue
        idxs = tuple(idxs)
        in_shape = model.layers[idxs[0]].in_shape
        fn = jax.jit(lambda x, i=idxs: ex._block_spatial(i, x, "int8"))
        x = jnp.zeros(in_shape, jnp.int8)
        np.asarray(fn(x))                         # compile
        wall = _time(lambda: np.asarray(fn(x)), iters)
        macs = sum(macs_for_positions(plan.splits[i].layer, sh.n_positions)
                   for i in idxs for sh in plan.splits[i].shards)
        gflops = 2.0 * macs / wall / 1e9
        entries[f"b{bi:02d}_L{idxs[0]}-{idxs[-1]}"] = dict(
            layers=list(idxs), wall_s=round(wall, 6), macs=int(macs),
            gflops=round(gflops, 3),
            roofline_frac=round(gflops / peak, 5))
    entries["_peak_gflops"] = round(peak, 2)
    return entries


def compile_section(model, plan, qm, hw: int) -> dict:
    """Trace/compile cost of the spatial int8 plan, and what the shared
    executable cache saves on a re-plan with identical geometry: ``cold_s``
    is construct+warmup from an empty cache, ``cached_s`` the same through a
    second executor instance (one cache hit, no re-trace)."""
    from repro.core import CompiledSplitExecutor

    CompiledSplitExecutor.cache_clear()
    t0 = time.perf_counter()
    ex = CompiledSplitExecutor(plan, qm)
    ex.warmup((3, hw, hw), batch=1, mode="int8")
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    ex2 = CompiledSplitExecutor(plan, qm)
    ex2.warmup((3, hw, hw), batch=1, mode="int8")
    cached = time.perf_counter() - t0
    stats = CompiledSplitExecutor.cache_stats()
    return {"spatial_int8_b1": dict(
        cold_s=round(cold, 6), cached_s=round(cached, 6),
        cache_hits=stats["hits"], cache_misses=stats["misses"])}


def bench_rows(quick: bool = False) -> tuple[list[dict], dict, dict, dict]:
    from repro.api import Session
    from repro.core import (CompiledSplitExecutor, SplitExecutor,
                            calibrate_scales, quantize_model,
                            reference_forward)

    rng = np.random.default_rng(0)
    rows: list[dict] = []
    peaks: dict[str, dict[str, int]] = {}
    roofline: dict[str, dict] = {}
    compile_times: dict[str, dict] = {}
    for name, make_model, hw, iters in _configs(quick):
        model = make_model()
        x = rng.standard_normal((3, hw, hw)).astype(np.float32)
        scales = calibrate_scales(
            model, [x],
            lambda m, xx: reference_forward(m, xx,
                                            collect_activations=True)[1])
        qm = quantize_model(model, scales)
        all_plans = build_plans(model)
        peaks[name] = peaks_for(model, all_plans)
        plans = {split: all_plans[split]
                 for split in ("neuron", "spatial")}  # timing rows
        xs = {b: np.stack([rng.standard_normal((3, hw, hw)).astype(np.float32)
                           for _ in range(b)]) for b in BATCHES}
        for split, plan in plans.items():
            eager = SplitExecutor(plan, qm)
            compiled = CompiledSplitExecutor(plan, qm)
            # spatial is benchmarked on the deployment path only (int8)
            modes = ("int8",) if split == "spatial" else ("float", "int8")
            for mode in modes:
                eager.run(x, mode=mode)             # warm per-op jit caches
                for batch in BATCHES:
                    data = xs[batch]
                    eager_s = _time(
                        lambda: [eager.run(data[i], mode=mode)
                                 for i in range(batch)],
                        iters)
                    compiled.warmup((3, hw, hw), batch=batch, mode=mode)
                    compiled_s = _time(
                        lambda: compiled.run_batch(data, mode=mode), iters)
                    rows.append(dict(config=name, split=split, mode=mode,
                                     batch=batch,
                                     eager_s=round(eager_s, 6),
                                     compiled_s=round(compiled_s, 6),
                                     speedup=round(eager_s / compiled_s, 2)))
        # serving-facade row: micro-batched submit_many vs per-request run()
        # (both on the compiled engine — the gap is batch amortization)
        bmax = max(BATCHES)
        session = Session(plans["neuron"], precision="int8", qmodel=qm,
                          max_batch=bmax, buckets=(1, bmax))
        session.warmup()
        data = xs[bmax]
        per_request_s = _time(
            lambda: [session.run(data[i]) for i in range(bmax)], iters)
        micro_batched_s = _time(lambda: session.submit_many(data), iters)
        rows.append(dict(config=name, split="session", mode="int8",
                         batch=bmax,
                         eager_s=round(per_request_s, 6),
                         compiled_s=round(micro_batched_s, 6),
                         speedup=round(per_request_s / micro_batched_s, 2)))
        # observability sections on the spatial int8 hot path
        roofline[name] = roofline_section(model, plans["spatial"], qm,
                                          iters=iters)
        compile_times[name] = compile_section(model, plans["spatial"], qm, hw)
    return rows, peaks, roofline, compile_times


def merge_sections(**sections) -> dict:
    """Merge per-outer-key updates into named sections of the shared
    ``BENCH_executor.json`` (read-modify-write: every section not named here
    survives untouched, and within a named section only the provided keys are
    replaced — a --quick or single-suite run never erases committed full-model
    entries).  Shared by this bench, ``kernel_bench`` and ``planner_bench``."""
    payload: dict = {}
    if RESULT_PATH.exists():
        try:
            payload = json.loads(RESULT_PATH.read_text())
        except json.JSONDecodeError:
            payload = {}
    for name, entries in sections.items():
        cur = dict(payload.get(name) or {})
        cur.update(entries)
        payload[name] = cur
    RESULT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def write_results(rows: list[dict], peaks: dict,
                  roofline: dict | None = None,
                  compile_times: dict | None = None) -> dict:
    import jax
    payload = dict(
        benchmark="executor_eager_vs_compiled",
        backend=jax.default_backend(),
        ratings=list(RATINGS),
        rows=rows,
        peaks=peaks,
    )
    # preserve every section this bench does not own (planner_bench's
    # planner/transport/mixed, kernel_bench's kernels — and anything future,
    # so a new shared section can never be silently erased by this write),
    # and merge per-config sections so a --quick run doesn't erase the
    # committed full-model entries
    if RESULT_PATH.exists():
        try:
            old = json.loads(RESULT_PATH.read_text())
        except json.JSONDecodeError:
            old = {}
        for section, value in old.items():
            if section not in payload:
                payload[section] = value
        merged_peaks = dict(old.get("peaks", {}))
        merged_peaks.update(payload["peaks"])
        payload["peaks"] = merged_peaks
    RESULT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    if roofline or compile_times:
        payload = merge_sections(roofline=roofline or {},
                                 compile=compile_times or {})
    return payload


def bench_executor(quick: bool = False) -> list[tuple]:
    """run.py suite entry: benchmark, persist JSON, return CSV rows."""
    rows, peaks, roofline, compile_times = bench_rows(quick=quick)
    write_results(rows, peaks, roofline, compile_times)
    out = []
    for r in rows:
        out.append((f"executor_{r['config']}_{r['split']}_{r['mode']}"
                    f"_b{r['batch']}",
                    r["compiled_s"],
                    f"eager={r['eager_s']}s speedup={r['speedup']}x"))
    for config, by_mode in peaks.items():
        for split, peak in by_mode.items():
            out.append((f"peak_{config}_{split}_kb", peak / 1024.0,
                        "max per-worker peak RAM"))
    for config, entry in compile_times.items():
        ct = entry["spatial_int8_b1"]
        out.append((f"compile_{config}_spatial_int8", ct["cold_s"],
                    f"cached={ct['cached_s']}s "
                    f"(executable cache: re-plan skips re-trace)"))
    out.append(("executor_bench_json", 1.0, str(RESULT_PATH.name)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke config only (CI)")
    args = ap.parse_args()
    rows, peaks, roofline, compile_times = bench_rows(quick=args.quick)
    payload = write_results(rows, peaks, roofline, compile_times)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
