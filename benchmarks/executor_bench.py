"""Eager vs compiled split-executor benchmark (the engine perf trajectory).

Measures wall-clock per batch for the eager reference ``SplitExecutor`` and
the jitted ``CompiledSplitExecutor`` over {config} x {float, int8} x
{batch 1, batch 8} on heterogeneous ratings, and writes the rows to
``BENCH_executor.json`` at the repo root:

    {config, mode, batch, eager_s, compiled_s, speedup}

Compilation is excluded (one warmup per compiled entry); the eager executor
is warmed once per mode so its per-op jit caches are hot too — the measured
gap is dispatch/host-sync vs a single fused XLA computation, not compile
time.

Run:  PYTHONPATH=src python -m benchmarks.executor_bench [--quick]
(--quick: smoke config only, fewer iters — used by the CI smoke run.)
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

_REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
RESULT_PATH = _REPO_ROOT / "BENCH_executor.json"

BATCHES = (1, 8)
RATINGS = (3.0, 1.0, 2.0, 0.5)          # heterogeneous 4-worker cluster


def _configs(quick: bool):
    from repro.models import mobilenet_v2_paper, mobilenet_v2_smoke
    cfgs = [("smoke", mobilenet_v2_smoke, 32, 3)]
    if not quick:
        cfgs.append(("mnv2_112", mobilenet_v2_paper, 112, 2))
    return cfgs


def _time(fn, iters: int) -> float:
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_rows(quick: bool = False) -> list[dict]:
    from repro.core import (CompiledSplitExecutor, SplitExecutor,
                            calibrate_scales, quantize_model,
                            reference_forward, split_model)

    rng = np.random.default_rng(0)
    rows: list[dict] = []
    for name, make_model, hw, iters in _configs(quick):
        model = make_model()
        x = rng.standard_normal((3, hw, hw)).astype(np.float32)
        scales = calibrate_scales(
            model, [x],
            lambda m, xx: reference_forward(m, xx,
                                            collect_activations=True)[1])
        qm = quantize_model(model, scales)
        plan = split_model(model, np.asarray(RATINGS))
        eager = SplitExecutor(plan, qm)
        compiled = CompiledSplitExecutor(plan, qm)
        xs = {b: np.stack([rng.standard_normal((3, hw, hw)).astype(np.float32)
                           for _ in range(b)]) for b in BATCHES}
        for mode in ("float", "int8"):
            eager.run(x, mode=mode)                 # warm per-op jit caches
            for batch in BATCHES:
                data = xs[batch]
                eager_s = _time(
                    lambda: [eager.run(data[i], mode=mode)
                             for i in range(batch)],
                    iters)
                compiled.warmup((3, hw, hw), batch=batch, mode=mode)
                compiled_s = _time(
                    lambda: compiled.run_batch(data, mode=mode), iters)
                rows.append(dict(config=name, mode=mode, batch=batch,
                                 eager_s=round(eager_s, 6),
                                 compiled_s=round(compiled_s, 6),
                                 speedup=round(eager_s / compiled_s, 2)))
    return rows


def write_results(rows: list[dict]) -> dict:
    import jax
    payload = dict(
        benchmark="executor_eager_vs_compiled",
        backend=jax.default_backend(),
        ratings=list(RATINGS),
        rows=rows,
    )
    RESULT_PATH.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def bench_executor(quick: bool = False) -> list[tuple]:
    """run.py suite entry: benchmark, persist JSON, return CSV rows."""
    rows = bench_rows(quick=quick)
    write_results(rows)
    out = []
    for r in rows:
        out.append((f"executor_{r['config']}_{r['mode']}_b{r['batch']}",
                    r["compiled_s"],
                    f"eager={r['eager_s']}s speedup={r['speedup']}x"))
    out.append(("executor_bench_json", 1.0, str(RESULT_PATH.name)))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smoke config only (CI)")
    args = ap.parse_args()
    rows = bench_rows(quick=args.quick)
    payload = write_results(rows)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
