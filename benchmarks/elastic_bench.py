"""Elastic-replan benchmark: churn recovery over the live coordinator.

Persists an ``elastic`` section into the shared ``BENCH_executor.json``
(via ``merge_sections``), keyed ``<config>@<n_workers>``.  Each measured
row drives the real churn loop (kill one worker mid-serve, rejoin it)
through :class:`~repro.runtime.replan.ElasticCoordinator` and records:

* ``bitexact_after_recovery`` — every phase's output equals the
  single-process ``Session`` on the surviving topology (hard invariant);
* ``reshipped_bytes`` / ``full_setup_bytes`` — delta shipping must beat a
  cold re-setup (hard invariant: reshipped < full);
* ``cache_hit_rate`` — every unchanged ``ShardGeometry`` must hit the
  worker's warm compiled-segment cache (hard invariant: 1.0 whenever
  ``expected_cache_hits`` > 0);
* ``leaked_tasks`` — asyncio tasks still pending after ``close()``
  (hard invariant: 0);
* ``downtime_kill_s`` / ``downtime_rejoin_s`` — wall-clock recovery
  time, machine-bound and informational only.

``--analytic`` skips the live coordinator entirely and emits only the
deterministic plan-diff rows (``diff_plans`` over a churn transition) —
the pinned-min CI cell gates those without spawning workers.

Run:  PYTHONPATH=src python -m benchmarks.elastic_bench [--quick|--analytic]
"""
from __future__ import annotations

import argparse
import json


def _analytic_rows() -> dict:
    """Deterministic plan-diff invariants: no workers, no wall clock."""
    from repro.api.planner import Objective
    from repro.core.allocation import WorkerParams
    from repro.models import mobilenet_v2_smoke
    from repro.runtime.elastic import ElasticCluster
    from repro.runtime.replan import diff_plans

    section = {}
    for n in (3, 4):
        cluster = ElasticCluster(
            mobilenet_v2_smoke(), [WorkerParams() for _ in range(n)],
            objective=Objective(modes=("spatial",)),
            heartbeat_timeout=1e9, clock=lambda: 0.0)
        old_split = cluster.plan.split
        old_ids = cluster.plan_worker_ids
        cluster.mark_failed(old_ids[0])
        cluster.check(now=0.0)
        by_pid = {pid: slot for slot, pid in enumerate(old_ids)}
        wmap = {slot: by_pid[pid]
                for slot, pid in enumerate(cluster.plan_worker_ids)
                if pid in by_pid}
        d = diff_plans(old_split, cluster.plan.split, qmodel=None,
                       precision="float", worker_map=wmap)
        section[f"mnv2_smoke@{n}"] = dict(
            n_workers=n,
            analytic=True,
            full_setup_bytes=d.full_setup_bytes,
            reshipped_bytes=d.reshipped_bytes,
            unchanged_segments=d.unchanged,
            moved_segments=d.moved,
            resized_segments=d.resized)
    return section


def _measured_rows(quick: bool = False) -> dict:
    """Live churn loop: kill -> serve -> rejoin over real workers."""
    import asyncio
    import numpy as np

    from repro.api.planner import Objective
    from repro.api.session import Session
    from repro.core.allocation import WorkerParams
    from repro.models import mobilenet_v2_smoke
    from repro.runtime.elastic import ElasticCluster
    from repro.runtime.replan import ElasticCoordinator

    counts = (3,) if quick else (3, 4)
    section = {}
    for n in counts:
        model = mobilenet_v2_smoke()
        cluster = ElasticCluster(
            model, [WorkerParams() for _ in range(n)],
            objective=Objective(modes=("spatial",)),
            heartbeat_timeout=1e9)
        qm = Session(cluster.plan.split, seed=0).qmodel
        rng = np.random.default_rng(0)
        xs = [rng.standard_normal(model.input_shape).astype(np.float32)
              for _ in range(2)]

        async def drive():
            out = {"phases": {}}
            ec = ElasticCoordinator(cluster, qm, spawn="inprocess")
            async with ec:
                out["phases"]["steady"] = [await ec.infer(x) for x in xs]
                victim = ec.physical_ids[0]
                await ec.inject_failure(0)
                out["phases"]["kill"] = [await ec.infer(x) for x in xs]
                out["surviving_split"] = ec.split
                await ec.rejoin(victim)
                out["phases"]["rejoin"] = [await ec.infer(x) for x in xs]
                out["reports"] = list(ec.reports)
            out["leaked"] = len(
                [t for t in asyncio.all_tasks()
                 if t is not asyncio.current_task() and not t.done()])
            return out

        res = asyncio.run(drive())
        oracle = Session(res["surviving_split"], qmodel=qm)
        ys_ref = [oracle.run(x) for x in xs]
        bitexact = all(
            len(ys) == len(xs)
            and all(np.array_equal(y, yr) for y, yr in zip(ys, ys_ref))
            for ys in res["phases"].values())
        kill, rejoin = res["reports"][0], res["reports"][-1]
        hit_rate = min(r["hit_rate"] for r in res["reports"])
        expected = sum(r["expected_cache_hits"] for r in res["reports"])
        section[f"mnv2_smoke@{n}"] = dict(
            n_workers=n,
            spawn="inprocess",
            bitexact_after_recovery=bool(bitexact),
            full_setup_bytes=kill["full_setup_bytes"],
            reshipped_bytes=kill["reshipped_bytes"],
            rejoin_full_setup_bytes=rejoin["full_setup_bytes"],
            rejoin_reshipped_bytes=rejoin["reshipped_bytes"],
            cache_hit_rate=hit_rate,
            expected_cache_hits=expected,
            leaked_tasks=res["leaked"],
            downtime_kill_s=round(kill["downtime_s"], 3),
            downtime_rejoin_s=round(rejoin["downtime_s"], 3))
    return section


def elastic_section(quick: bool = False, analytic: bool = False) -> dict:
    return _analytic_rows() if analytic else _measured_rows(quick)


def bench_elastic(quick: bool = False) -> list[tuple]:
    """run.py suite entry: persist the ``elastic`` BENCH section, return
    CSV rows."""
    from benchmarks.executor_bench import merge_sections

    section = elastic_section(quick)
    merge_sections(elastic=section)
    rows = []
    for key, e in section.items():
        rows.append((f"elastic_{key}_downtime_kill_s", e["downtime_kill_s"],
                     f"bitexact={e['bitexact_after_recovery']} "
                     f"reshipped={e['reshipped_bytes']}/"
                     f"{e['full_setup_bytes']}B "
                     f"hit_rate={e['cache_hit_rate']}"))
        rows.append((f"elastic_{key}_downtime_rejoin_s",
                     e["downtime_rejoin_s"],
                     f"reshipped={e['rejoin_reshipped_bytes']}/"
                     f"{e['rejoin_full_setup_bytes']}B "
                     f"leaked={e['leaked_tasks']}"))
    return rows


def main(argv: list[str] | None = None) -> None:
    from benchmarks.executor_bench import merge_sections

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--analytic", action="store_true",
                    help="plan-diff rows only; no live workers")
    args = ap.parse_args(argv)
    section = elastic_section(quick=args.quick, analytic=args.analytic)
    payload = merge_sections(elastic=section)
    print(json.dumps({"elastic": payload["elastic"]}, indent=2))


if __name__ == "__main__":
    main()
